//! Graph IR — the Rust mirror of `python/compile/graphir.py`.
//!
//! Both sides round-trip the same JSON; integration tests feed the
//! Python-emitted manifest graphs through this parser and through the
//! Rust merge planner (`crate::fuse`) and compare against the Python
//! merge output.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Merge dimension classification (paper §3, Algorithm 1 lines 12-16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MergeDim {
    Batch,
    Channel,
    DontCare,
}

/// Attribute value: ints dominate, a couple of ops use strings.
#[derive(Debug, Clone, PartialEq)]
pub enum Attr {
    Int(i64),
    Str(String),
    Bool(bool),
}

impl Attr {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Attr::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attr::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// One operation.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: String,
    pub kind: String,
    pub inputs: Vec<String>,
    pub attrs: BTreeMap<String, Attr>,
    /// ordered weight name -> shape
    pub weights: BTreeMap<String, Vec<usize>>,
    pub mergeable: bool,
}

impl Node {
    pub fn attr_i64(&self, key: &str) -> Result<i64> {
        self.attrs
            .get(key)
            .and_then(|a| a.as_i64())
            .with_context(|| format!("node {}: missing int attr {key:?}", self.id))
    }

    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        Ok(self.attr_i64(key)? as usize)
    }

    /// Total parameter bytes of this node (f32).
    pub fn weight_bytes(&self) -> u64 {
        4 * self
            .weights
            .values()
            .map(|s| s.iter().product::<usize>() as u64)
            .sum::<u64>()
    }
}

/// The merge dimension an op kind demands, or None for unknown kinds.
pub fn merge_dim(kind: &str) -> Option<MergeDim> {
    use MergeDim::*;
    Some(match kind {
        "dense" | "attention" | "xl_attention" => Batch,
        "conv2d" | "layernorm" | "batchnorm" | "groupnorm" => Channel,
        "relu" | "gelu" | "add" | "maxpool2d" | "global_avgpool"
        | "flatten" | "refmt" | "slice_m" | "stack_m" => DontCare,
        _ => return None,
    })
}

/// Whether a kind carries weights.
pub fn is_trainable(kind: &str) -> bool {
    matches!(
        kind,
        "conv2d" | "dense" | "layernorm" | "batchnorm" | "groupnorm"
            | "attention" | "xl_attention"
    )
}

/// A DNN as a topologically ordered op list.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    /// Input shape *excluding* batch: CNN (C, H, W); sequence (S, H).
    pub input_shape: Vec<usize>,
    pub nodes: Vec<Node>,
    pub output: String,
    pub merged_m: usize,
    /// "single" | "channel" | "batch"
    pub layout: String,
}

impl Graph {
    pub fn node(&self, id: &str) -> Result<&Node> {
        self.nodes
            .iter()
            .find(|n| n.id == id)
            .with_context(|| format!("no node {id:?} in graph {:?}", self.name))
    }

    pub fn consumers(&self, id: &str) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.iter().any(|s| s == id))
            .collect()
    }

    /// Structural validation — same rules as `graphir.Graph.validate`.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            bail!("empty graph");
        }
        let mut seen = std::collections::HashSet::new();
        for n in &self.nodes {
            if n.id == "input" || !seen.insert(n.id.as_str()) {
                bail!("duplicate/reserved node id {:?}", n.id);
            }
            if merge_dim(&n.kind).is_none() {
                bail!("unknown op kind {:?}", n.kind);
            }
            for src in &n.inputs {
                if src != "input" && !seen.contains(src.as_str()) {
                    bail!(
                        "node {:?} uses {:?} before definition (not topo-ordered)",
                        n.id, src
                    );
                }
            }
            if is_trainable(&n.kind) && n.weights.is_empty() {
                bail!("trainable node {:?} has no weights", n.id);
            }
            if !is_trainable(&n.kind) && !n.weights.is_empty() {
                bail!("non-trainable node {:?} has weights", n.id);
            }
        }
        if !seen.contains(self.output.as_str()) {
            bail!("output {:?} is not a node", self.output);
        }
        Ok(())
    }

    /// Total parameter bytes (one instance).
    pub fn weight_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.weight_bytes()).sum()
    }

    /// Deterministic parameter order shared with the Python lowering:
    /// topo node order, then sorted weight names within a node.
    pub fn param_order(&self) -> Vec<String> {
        let mut out = Vec::new();
        for n in &self.nodes {
            for w in n.weights.keys() {
                out.push(format!("{}.{}", n.id, w));
            }
        }
        out
    }

    // ---------------------------------------------------------------- JSON

    pub fn from_json(v: &Json) -> Result<Graph> {
        let name = v.get("name").as_str().context("graph.name")?.to_string();
        let input_shape = usize_vec(v.get("input_shape")).context("graph.input_shape")?;
        let output = v.get("output").as_str().context("graph.output")?.to_string();
        let merged_m = v.get("merged_m").as_usize().unwrap_or(1);
        let layout = v
            .get("layout")
            .as_str()
            .unwrap_or("single")
            .to_string();
        let mut nodes = Vec::new();
        for nv in v.get("nodes").as_arr().context("graph.nodes")? {
            nodes.push(node_from_json(nv)?);
        }
        let g = Graph { name, input_shape, nodes, output, merged_m, layout };
        g.validate()?;
        Ok(g)
    }

    pub fn parse(text: &str) -> Result<Graph> {
        Graph::from_json(&Json::parse(text)?)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            (
                "input_shape",
                json::arr(self.input_shape.iter().map(|d| json::num(*d as f64))),
            ),
            (
                "nodes",
                json::arr(self.nodes.iter().map(node_to_json)),
            ),
            ("output", json::s(&self.output)),
            ("merged_m", json::num(self.merged_m as f64)),
            ("layout", json::s(&self.layout)),
        ])
    }
}

fn usize_vec(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .context("expected array")?
        .iter()
        .map(|x| x.as_usize().context("expected unsigned int"))
        .collect()
}

fn node_from_json(v: &Json) -> Result<Node> {
    let id = v.get("id").as_str().context("node.id")?.to_string();
    let kind = v.get("kind").as_str().context("node.kind")?.to_string();
    let inputs = v
        .get("inputs")
        .as_arr()
        .context("node.inputs")?
        .iter()
        .map(|x| x.as_str().map(str::to_string).context("input id"))
        .collect::<Result<Vec<_>>>()?;
    let mut attrs = BTreeMap::new();
    if let Some(o) = v.get("attrs").as_obj() {
        for (k, av) in o {
            let a = match av {
                Json::Num(n) => Attr::Int(*n as i64),
                Json::Str(s) => Attr::Str(s.clone()),
                Json::Bool(b) => Attr::Bool(*b),
                other => bail!("node {id}: bad attr {k:?}: {other:?}"),
            };
            attrs.insert(k.clone(), a);
        }
    }
    let mut weights = BTreeMap::new();
    if let Some(o) = v.get("weights").as_obj() {
        for (k, wv) in o {
            weights.insert(k.clone(), usize_vec(wv)?);
        }
    }
    let mergeable = v.get("mergeable").as_bool().unwrap_or(true);
    Ok(Node { id, kind, inputs, attrs, weights, mergeable })
}

fn node_to_json(n: &Node) -> Json {
    let attrs = Json::Obj(
        n.attrs
            .iter()
            .map(|(k, a)| {
                let v = match a {
                    Attr::Int(i) => json::num(*i as f64),
                    Attr::Str(s) => json::s(s),
                    Attr::Bool(b) => Json::Bool(*b),
                };
                (k.clone(), v)
            })
            .collect(),
    );
    let weights = Json::Obj(
        n.weights
            .iter()
            .map(|(k, shape)| {
                (
                    k.clone(),
                    json::arr(shape.iter().map(|d| json::num(*d as f64))),
                )
            })
            .collect(),
    );
    json::obj(vec![
        ("id", json::s(&n.id)),
        ("kind", json::s(&n.kind)),
        ("inputs", json::arr(n.inputs.iter().map(|s| json::s(s)))),
        ("attrs", attrs),
        ("weights", weights),
        ("mergeable", Json::Bool(n.mergeable)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        Graph::parse(
            r#"{
              "name": "t", "input_shape": [4], "output": "d",
              "nodes": [
                {"id": "d", "kind": "dense", "inputs": ["input"],
                 "attrs": {"fin": 4, "fout": 2},
                 "weights": {"w": [4, 2], "b": [2]}, "mergeable": true}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_roundtrips() {
        let g = tiny();
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.node("d").unwrap().attr_usize("fin").unwrap(), 4);
        let g2 = Graph::parse(&g.to_json().dump()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn param_order_sorted_within_node() {
        let g = tiny();
        assert_eq!(g.param_order(), vec!["d.b", "d.w"]);
    }

    #[test]
    fn validate_catches_unknown_kind() {
        let mut g = tiny();
        g.nodes[0].kind = "warp".into();
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_forward_ref() {
        let mut g = tiny();
        g.nodes[0].inputs = vec!["later".into()];
        assert!(g.validate().is_err());
    }

    #[test]
    fn weight_bytes_counts() {
        let g = tiny();
        assert_eq!(g.weight_bytes(), 4 * (8 + 2));
    }
}
