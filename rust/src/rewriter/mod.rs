//! Miniature TASO-like greedy graph-rewriting baseline (paper §2.2).
//!
//! The paper argues that existing graph-rewriting frameworks cannot
//! discover NETFUSE's cross-model merge: (i) greedy cost-based search
//! prefers single-model substitutions because the cross-model rewrite is
//! "hidden behind overheads" (the reshape/concat fix-ups look like pure
//! cost before the grouped kernel pays off), and (ii) the search space
//! explodes with the number of disjoint models.
//!
//! This module reproduces that argument with a small substitution-rule
//! engine over the shared graph IR: a rule set of classic single-model
//! rewrites plus an *optional* cross-model grouped-conv rule, and a
//! greedy best-first search with a device-model cost function. Bench
//! `fig2_rewriter` shows greedy search with the default (single-model)
//! rules never merges across models, while NETFUSE's targeted Algorithm 1
//! does — and that rewrite search time grows steeply with model count.

use std::collections::BTreeMap;

use crate::devmodel::{self, GpuProfile};
use crate::graph::{Attr, Graph, Node};

/// A rewrite rule: recognizes a local pattern, returns the rewritten
/// graph when it applies (first match).
pub struct Rule {
    pub name: &'static str,
    /// true for rewrites that reach across models (disabled in the
    /// default TASO-like rule set — that is the point of Figure 2)
    pub cross_model: bool,
    pub apply: fn(&Graph) -> Option<Graph>,
}

/// Classic single-model rules (conv+bn fold, conv+relu fuse, dead refmt).
pub fn default_rules() -> Vec<Rule> {
    vec![
        Rule { name: "fold-bn-into-conv", cross_model: false, apply: fold_bn },
        Rule { name: "fuse-conv-relu", cross_model: false, apply: fuse_conv_relu },
        Rule { name: "drop-noop-refmt", cross_model: false, apply: drop_noop_refmt },
    ]
}

/// The rule NETFUSE encodes directly and greedy search misses: merge two
/// same-shape convs with different inputs/weights into a grouped conv.
pub fn cross_model_rule() -> Rule {
    Rule {
        name: "merge-parallel-convs-grouped",
        cross_model: true,
        apply: merge_parallel_convs,
    }
}

// ---------------------------------------------------------------------------
// cost model: sum of per-op device-model costs (greedy's objective)
// ---------------------------------------------------------------------------

/// Rough per-node cost for the greedy objective. Includes the launch
/// overhead so fusing ops pays off, and charges refmt/concat fix-ups —
/// which is exactly why a *greedy* search rejects the cross-model merge:
/// the intermediate state (concat + reshape inserted, grouped conv not
/// yet applied everywhere) costs more than the original graph.
pub fn node_cost(p: &GpuProfile, g: &Graph, n: &Node, bs: usize) -> f64 {
    let b = bs as f64;
    let cost = match n.kind.as_str() {
        "conv2d" => {
            let cin = n.attr_i64("cin").unwrap_or(1) as f64;
            let cout = n.attr_i64("cout").unwrap_or(1) as f64;
            let k = n.attr_i64("k").unwrap_or(1) as f64;
            let groups = n.attr_i64("groups").unwrap_or(1) as f64;
            let hw = g.input_shape.get(1).copied().unwrap_or(16) as f64;
            devmodel::op(
                2.0 * b * cout * (cin / groups) * k * k * hw * hw,
                4.0 * b * (cin + cout) * hw * hw,
                b * cout * hw * hw,
            )
        }
        "dense" => {
            let fin = n.attr_i64("fin").unwrap_or(1) as f64;
            let fout = n.attr_i64("fout").unwrap_or(1) as f64;
            devmodel::op(
                2.0 * b * fin * fout,
                4.0 * (b * fin + fin * fout + b * fout),
                b * fout,
            )
        }
        _ => {
            // elementwise-ish: bandwidth bound on the input tensor
            let elems = b * g.input_shape.iter().product::<usize>() as f64;
            devmodel::op(elems, 8.0 * elems, elems)
        }
    };
    p.launch_s + cost.compute_s(p)
}

pub fn graph_cost(p: &GpuProfile, g: &Graph, bs: usize) -> f64 {
    g.nodes.iter().map(|n| node_cost(p, g, n, bs)).sum()
}

// ---------------------------------------------------------------------------
// greedy search
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct SearchResult {
    pub graph: Graph,
    pub initial_cost: f64,
    pub final_cost: f64,
    pub applied: Vec<&'static str>,
    pub states_explored: usize,
}

/// Greedy best-first: repeatedly apply the single rule application that
/// lowers cost the most; stop when nothing improves. This is the
/// TASO-like baseline (TASO adds backtracking within a window, but its
/// published failure mode on multi-model graphs is the same: the merge
/// is not reachable through cost-decreasing steps).
pub fn greedy_optimize(
    p: &GpuProfile,
    g: &Graph,
    rules: &[Rule],
    bs: usize,
) -> SearchResult {
    let mut cur = g.clone();
    let initial_cost = graph_cost(p, &cur, bs);
    let mut cost = initial_cost;
    let mut applied = Vec::new();
    let mut states = 1usize;
    loop {
        let mut best: Option<(f64, Graph, &'static str)> = None;
        for rule in rules {
            if let Some(cand) = (rule.apply)(&cur) {
                states += 1;
                let c = graph_cost(p, &cand, bs);
                if c < cost && best.as_ref().map(|(bc, _, _)| c < *bc).unwrap_or(true)
                {
                    best = Some((c, cand, rule.name));
                }
            }
        }
        match best {
            Some((c, g2, name)) => {
                cost = c;
                cur = g2;
                applied.push(name);
            }
            None => break,
        }
    }
    SearchResult {
        graph: cur,
        initial_cost,
        final_cost: cost,
        applied,
        states_explored: states,
    }
}

/// Exhaustive-ish state count for `n_models` disjoint copies — the §2.2
/// scalability argument (TASO: 30 h for 4 models, OOM at 8). Each model
/// contributes an independent set of applicable rewrite sites, so the
/// joint space multiplies.
pub fn search_space_size(per_model_sites: usize, n_models: usize) -> f64 {
    // 2^(sites * models): each site toggled independently
    2f64.powi((per_model_sites * n_models) as i32)
}

// ---------------------------------------------------------------------------
// rule implementations
// ---------------------------------------------------------------------------

/// conv followed by batchnorm -> conv (BN folded into weights).
fn fold_bn(g: &Graph) -> Option<Graph> {
    for (i, n) in g.nodes.iter().enumerate() {
        if n.kind != "batchnorm" {
            continue;
        }
        let src = &n.inputs[0];
        let Some(parent) = g.nodes.iter().find(|x| &x.id == src) else {
            continue;
        };
        if parent.kind != "conv2d" || g.consumers(src).len() != 1 {
            continue;
        }
        // rewrite: bn node disappears; conv absorbs it (weights unchanged
        // structurally — folding is a value-level transform)
        let mut nodes = g.nodes.clone();
        nodes.remove(i);
        let bn_id = n.id.clone();
        let conv_id = parent.id.clone();
        for x in &mut nodes {
            for inp in &mut x.inputs {
                if *inp == bn_id {
                    *inp = conv_id.clone();
                }
            }
        }
        let mut g2 = g.clone();
        g2.nodes = nodes;
        if g2.output == bn_id {
            g2.output = conv_id;
        }
        return Some(g2);
    }
    None
}

/// conv followed by relu -> conv with fused activation attr.
fn fuse_conv_relu(g: &Graph) -> Option<Graph> {
    for (i, n) in g.nodes.iter().enumerate() {
        if n.kind != "relu" {
            continue;
        }
        let src = &n.inputs[0];
        let Some(parent_idx) = g.nodes.iter().position(|x| &x.id == src) else {
            continue;
        };
        if g.nodes[parent_idx].kind != "conv2d"
            || g.consumers(src).len() != 1
            || g.nodes[parent_idx].attrs.contains_key("fused_relu")
        {
            continue;
        }
        let mut g2 = g.clone();
        g2.nodes[parent_idx]
            .attrs
            .insert("fused_relu".into(), Attr::Bool(true));
        let relu_id = n.id.clone();
        let conv_id = g2.nodes[parent_idx].id.clone();
        g2.nodes.remove(i);
        for x in &mut g2.nodes {
            for inp in &mut x.inputs {
                if *inp == relu_id {
                    *inp = conv_id.clone();
                }
            }
        }
        if g2.output == relu_id {
            g2.output = conv_id;
        }
        return Some(g2);
    }
    None
}

/// refmt with src == dst is a no-op.
fn drop_noop_refmt(g: &Graph) -> Option<Graph> {
    for (i, n) in g.nodes.iter().enumerate() {
        if n.kind == "refmt"
            && n.attrs.get("src").and_then(|a| a.as_str())
                == n.attrs.get("dst").and_then(|a| a.as_str())
        {
            let mut g2 = g.clone();
            let rid = n.id.clone();
            let src = n.inputs[0].clone();
            g2.nodes.remove(i);
            for x in &mut g2.nodes {
                for inp in &mut x.inputs {
                    if *inp == rid {
                        *inp = src.clone();
                    }
                }
            }
            if g2.output == rid {
                g2.output = src;
            }
            return Some(g2);
        }
    }
    None
}

/// Two conv2d nodes with identical attrs but different inputs/weights
/// -> one grouped conv over channel-concatenated inputs (Figure 2b).
fn merge_parallel_convs(g: &Graph) -> Option<Graph> {
    let convs: Vec<&Node> = g
        .nodes
        .iter()
        .filter(|n| n.kind == "conv2d" && !n.attrs.contains_key("merged_pair"))
        .collect();
    for (ai, a) in convs.iter().enumerate() {
        for b in convs.iter().skip(ai + 1) {
            if a.inputs == b.inputs || a.attrs != b.attrs {
                continue;
            }
            // build: concat(a.in, b.in) -> grouped conv -> split outputs.
            // Consumers of a and b get the split halves via slice markers.
            let mut g2 = g.clone();
            let cin = a.attr_i64("cin").ok()? as usize;
            let cout = a.attr_i64("cout").ok()? as usize;
            let groups = a.attr_i64("groups").ok()? as usize;
            let k = a.attr_i64("k").ok()? as usize;
            let merged_id = format!("{}__grouped__{}", a.id, b.id);
            let mut attrs = a.attrs.clone();
            attrs.insert("cin".into(), Attr::Int(2 * cin as i64));
            attrs.insert("cout".into(), Attr::Int(2 * cout as i64));
            attrs.insert("groups".into(), Attr::Int(2 * groups as i64));
            attrs.insert("merged_pair".into(), Attr::Bool(true));
            let mut weights = BTreeMap::new();
            weights.insert("w".into(), vec![2 * cout, cin / groups, k, k]);
            weights.insert("b".into(), vec![2 * cout]);
            // concat node (the overhead that scares greedy away)
            let concat_id = format!("{merged_id}__concat");
            g2.nodes.push(Node {
                id: concat_id.clone(),
                kind: "refmt".into(),
                inputs: vec![a.inputs[0].clone(), b.inputs[0].clone()],
                attrs: BTreeMap::from([
                    ("src".to_string(), Attr::Str("pair".into())),
                    ("dst".to_string(), Attr::Str("channel".into())),
                ]),
                weights: BTreeMap::new(),
                mergeable: true,
            });
            g2.nodes.push(Node {
                id: merged_id.clone(),
                kind: "conv2d".into(),
                inputs: vec![concat_id],
                attrs,
                weights,
                mergeable: true,
            });
            // rewire consumers through slice markers
            for (half, orig) in [(0usize, a.id.clone()), (1, b.id.clone())] {
                let sid = format!("{merged_id}__half{half}");
                g2.nodes.push(Node {
                    id: sid.clone(),
                    kind: "slice_m".into(),
                    inputs: vec![merged_id.clone()],
                    attrs: BTreeMap::from([
                        ("index".to_string(), Attr::Int(half as i64)),
                    ]),
                    weights: BTreeMap::new(),
                    mergeable: true,
                });
                for x in &mut g2.nodes {
                    if x.id == sid {
                        continue;
                    }
                    for inp in &mut x.inputs {
                        if *inp == orig {
                            *inp = sid.clone();
                        }
                    }
                }
                if g2.output == orig {
                    g2.output = sid.clone();
                }
            }
            // remove the originals
            g2.nodes.retain(|n| n.id != a.id && n.id != b.id);
            // keep topological order: move appended nodes before consumers
            g2 = retopo(&g2)?;
            return Some(g2);
        }
    }
    None
}

/// Re-topo-sort a graph whose node list order may be stale.
fn retopo(g: &Graph) -> Option<Graph> {
    let mut placed: std::collections::HashSet<String> =
        std::collections::HashSet::from(["input".to_string()]);
    let mut nodes = Vec::with_capacity(g.nodes.len());
    let mut remaining: Vec<Node> = g.nodes.clone();
    while !remaining.is_empty() {
        let before = remaining.len();
        remaining.retain(|n| {
            if n.inputs.iter().all(|i| placed.contains(i)) {
                placed.insert(n.id.clone());
                nodes.push(n.clone());
                false
            } else {
                true
            }
        });
        if remaining.len() == before {
            return None; // cycle
        }
    }
    let mut g2 = g.clone();
    g2.nodes = nodes;
    Some(g2)
}
