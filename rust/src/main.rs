//! `netfuse` — the serving coordinator CLI.
//!
//! ```text
//! netfuse inspect                       list artifacts + models
//! netfuse merge-plan  --model M --m N   run Algorithm 1, print the plan
//! netfuse serve       --model M --m N --strategy S --rounds R
//! netfuse bench-figure <fig2|fig5|fig6|fig7|fig8|fig9|fig10|merge-overhead>
//! ```
//!
//! All subcommands are offline-complete: Python never runs here; the
//! artifact directory produced by `make artifacts` is the only input.

use std::path::PathBuf;
use std::process::ExitCode;

use netfuse::coordinator::server::{Server, ServerConfig};
use netfuse::coordinator::workload::Workload;
use netfuse::coordinator::{Fleet, StrategyKind};
use netfuse::devmodel;
use netfuse::figures::{self, FigOpts};
use netfuse::fuse;
use netfuse::runtime::Runtime;
use netfuse::util::cli::Args;
use netfuse::util::stats::fmt_bytes;

const USAGE: &str = "\
netfuse — multi-model inference by merging DNNs of different weights

USAGE:
  netfuse <COMMAND> [OPTIONS]

COMMANDS:
  inspect                         list artifacts and model families
  merge-plan                      run Algorithm 1 and print the merged graph
  serve                           run the serving loop and report metrics
  bench-figure <id>               regenerate a paper figure (fig2, fig5,
                                  fig6, fig7, fig8, fig9, fig10,
                                  merge-overhead, all)

OPTIONS:
  --artifacts <dir>   artifact directory        [default: ./artifacts]
  --model <name>      resnet|resnext|bert|xlnet [default: bert]
  --models <a,b,..>   model list for figures    [default: all four]
  --m <n>             number of model instances [default: 4]
  --bs <n>            request batch size        [default: 1]
  --strategy <s>      sequential|concurrent|hybrid:<p>|netfuse
  --rounds <n>        serving rounds            [default: 50]
  --rate <r>          per-model arrivals/sec    [default: 200]
  --m-sweep <a,b,..>  instance counts for figures
  --samples <n>       measurement samples       [default: 10]
  --device <d>        v100|titanxp              [default: v100]
  --sim-only          skip CPU measurements (device model only)
  --quick             small sweeps (CI-speed)
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &argv,
        &[
            "artifacts", "model", "models", "m", "bs", "strategy", "rounds",
            "rate", "m-sweep", "samples", "device",
        ],
        &["sim-only", "quick", "help"],
    )
    .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))?;

    if args.has("help") || args.positional().is_empty() {
        println!("{USAGE}");
        return Ok(());
    }

    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let cmd = args.positional()[0].as_str();

    match cmd {
        "inspect" => inspect(&artifacts),
        "merge-plan" => merge_plan(&artifacts, &args),
        "serve" => serve(&artifacts, &args),
        "bench-figure" => bench_figure(&artifacts, &args),
        other => anyhow::bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn inspect(artifacts: &PathBuf) -> anyhow::Result<()> {
    let rt = Runtime::open(artifacts)?;
    println!("platform: {}", rt.platform());
    println!("\nmodels:");
    for (name, entry) in &rt.manifest.models {
        println!(
            "  {:<10} {} nodes, {} instances, weights {} ({})",
            name,
            entry.graph.nodes.len(),
            entry.instances,
            entry.weights,
            fmt_bytes(entry.graph.weight_bytes()),
        );
    }
    println!("\nartifacts:");
    for a in &rt.manifest.artifacts {
        println!(
            "  {:<28} m={:<3} bs={} backend={:<7} in={:?} out={:?}",
            a.name, a.m, a.bs, a.backend, a.input_shape, a.output_shape
        );
    }
    Ok(())
}

fn merge_plan(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let rt = Runtime::open(artifacts)?;
    let model = args.get_or("model", "bert");
    let m = args.get_usize("m", 4).map_err(|e| anyhow::anyhow!(e))?;
    let g = &rt.manifest.model(model)?.graph;
    let merged = fuse::merge(g, m)?;
    println!(
        "# Algorithm 1: {} x{} -> {} ({} nodes -> {} nodes)",
        model,
        m,
        merged.name,
        g.nodes.len(),
        merged.nodes.len()
    );
    for n in &merged.nodes {
        let w: Vec<String> = n
            .weights
            .iter()
            .map(|(k, s)| format!("{k}:{s:?}"))
            .collect();
        println!(
            "  {:<24} {:<12} <- {:<30} {}",
            n.id,
            n.kind,
            n.inputs.join(", "),
            w.join(" ")
        );
    }
    println!("# output: {}  layout: {}", merged.output, merged.layout);
    Ok(())
}

fn serve(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let rt = Runtime::open(artifacts)?;
    let model = args.get_or("model", "bert");
    let m = args.get_usize("m", 4).map_err(|e| anyhow::anyhow!(e))?;
    let bs = args.get_usize("bs", 1).map_err(|e| anyhow::anyhow!(e))?;
    let rounds = args.get_usize("rounds", 50).map_err(|e| anyhow::anyhow!(e))?;
    let rate = args.get_f64("rate", 200.0).map_err(|e| anyhow::anyhow!(e))?;
    let strategy = StrategyKind::parse(args.get_or("strategy", "netfuse"))?;

    println!("loading fleet: {model} x{m} bs={bs} ({})", rt.platform());
    let fleet = Fleet::load(&rt, model, m, bs)?;
    let mut server = Server::new(&fleet, ServerConfig { strategy, ..Default::default() });
    let mut workload = Workload::new(m, &fleet.request_shape(), rate, 0xBEEF);

    let served = server.run_rounds(rounds, || workload.round())?;
    println!("served {served} requests over {rounds} rounds");
    println!("{}", server.metrics.report_line());
    println!(
        "throughput: {:.1} req/s   p50 {:.2}ms   p99 {:.2}ms",
        server.metrics.throughput(),
        server.metrics.request_latency.p50() * 1e3,
        server.metrics.request_latency.p99() * 1e3,
    );
    Ok(())
}

fn bench_figure(artifacts: &PathBuf, args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional()
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let mut opts = if args.has("quick") {
        FigOpts::quick()
    } else {
        FigOpts::default()
    };
    opts.models = args.get_list("models", &figures::MODELS);
    if let Some(sweep) = args.get("m-sweep") {
        opts.m_sweep = sweep
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("--m-sweep: {e}"))?;
    }
    opts.samples = args
        .get_usize("samples", opts.samples)
        .map_err(|e| anyhow::anyhow!(e))?;
    opts.measured = !args.has("sim-only");
    if let Some(d) = args.get("device") {
        opts.device = devmodel::profile(d)
            .ok_or_else(|| anyhow::anyhow!("unknown device {d:?} (v100|titanxp)"))?;
    }

    let rt = if opts.measured || matches!(id, "merge-overhead" | "all") {
        Some(Runtime::open(artifacts)?)
    } else {
        None
    };
    let rt_ref = rt.as_ref();

    let run = |name: &str| -> anyhow::Result<String> {
        match name {
            "fig2" => figures::fig2(),
            "fig5" => figures::fig5(rt_ref, &opts),
            "fig6" => figures::fig6(rt_ref, &opts),
            "fig7" => {
                let mut s = figures::fig7(&opts)?;
                if let Some(rt) = rt_ref {
                    s.push('\n');
                    s.push_str(&figures::fig7_measured(rt, &opts)?);
                }
                Ok(s)
            }
            "fig8" => figures::fig8(rt_ref, &opts),
            "fig9" => {
                let mut o = opts.clone();
                o.device = devmodel::TITAN_XP;
                o.measured = false; // CPU numbers identical to fig5's
                figures::fig5(None, &o)
            }
            "fig10" => {
                let mut o = opts.clone();
                o.device = devmodel::TITAN_XP;
                figures::fig7(&o)
            }
            "merge-overhead" => figures::merge_overhead(
                rt_ref.expect("merge-overhead needs artifacts"),
                &opts,
            ),
            other => anyhow::bail!("unknown figure {other:?}"),
        }
    };

    if id == "all" {
        for name in [
            "fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
            "merge-overhead",
        ] {
            println!("{}", run(name)?);
        }
    } else {
        println!("{}", run(id)?);
    }
    Ok(())
}
