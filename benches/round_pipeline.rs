//! Bench: the zero-copy round pipeline (host data plane).
//!
//! Compares the seed's copying round path — fresh `slot`/`inputs`
//! vectors, `Tensor::concat`/`stack` megabatch materialization, and
//! `index0` per-instance output copies — against the arena path:
//! `RoundArena::pack_with` into a reusable megabatch, borrowed
//! `TensorView` unpacking, and reusable dispatch scratch. Also measures
//! per-round `std::thread::scope` spawning (the seed's Concurrent
//! dispatch) against the persistent `WorkerPool`.
//!
//! Asserts, with a counting global allocator, that the steady-state
//! arena round performs **zero** heap allocations, and that the arena
//! round beats the legacy round by >= 2x at m=16 on mini-model-shaped
//! payloads. Also measures a half-padded steady state and asserts the
//! occupancy tracker never re-copies the zero pad block into windows
//! that stayed absent. Results are written to
//! `BENCH_round_pipeline.json`.
//!
//! Runs fully offline: the host data plane needs no artifacts and no
//! PJRT backend.

use std::collections::BTreeMap;

use netfuse::coordinator::arena::{Layout, RoundArena};
use netfuse::coordinator::pool::WorkerPool;
use netfuse::tensor::Tensor;
use netfuse::util::bench::counting_alloc::{self, CountingAlloc};
use netfuse::util::bench::{Bench, Config};
use netfuse::util::json::Json;
use netfuse::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const M: usize = 16;

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// One layout scenario: legacy round vs arena round over identical
/// payloads. Returns (legacy_s, arena_s, padded_s, allocs_per_round).
fn bench_layout(
    b: &mut Bench,
    layout: Layout,
    request_shape: &[usize],
    rng: &mut Rng,
) -> anyhow::Result<(f64, f64, f64, u64)> {
    let name = match layout {
        Layout::Channel => "channel",
        Layout::Batch => "batch",
    };
    let xs: Vec<Tensor> = (0..M).map(|_| Tensor::randn(request_shape, rng)).collect();
    let pad = Tensor::zeros(request_shape);
    // merged OUTPUT stand-in: always batch-packed [M, bs, ...]; identity
    // output shape keeps pack and unpack traffic comparable
    let mut out_shape = vec![M];
    out_shape.extend_from_slice(request_shape);
    let y = Tensor::randn(&out_shape, rng);

    // --- legacy path: the seed's dispatch, reconstructed ---------------
    let legacy = b.run(&format!("round/{name}/legacy m={M}"), || {
        // fresh per-round scratch, exactly like the seed's dispatch
        let slot: Vec<Option<&Tensor>> = (0..M).map(|i| Some(&xs[i])).collect();
        let inputs: Vec<&Tensor> = slot
            .iter()
            .map(|s| s.unwrap_or(&pad))
            .collect();
        // copying pack: concat/stack materializes a fresh megabatch
        let merged = match layout {
            Layout::Channel => Tensor::concat(&inputs, 1).unwrap(),
            Layout::Batch => Tensor::stack(&inputs).unwrap(),
        };
        std::hint::black_box(merged.data());
        // copying unpack: one owned tensor per instance
        let outs: Vec<Tensor> = (0..M).map(|i| y.index0(i).unwrap()).collect();
        std::hint::black_box(&outs);
    });

    // --- arena path: reusable megabatch + views + reused scratch -------
    let mut arena = RoundArena::new(layout, M, request_shape)?;
    let mut slots: Vec<Option<&Tensor>> = Vec::with_capacity(M);
    let mut views = Vec::with_capacity(M);
    let mut arena_round = || {
        slots.clear();
        for x in &xs {
            slots.push(Some(x));
        }
        let get = |i: usize| slots[i];
        arena.pack_with(&get).unwrap();
        std::hint::black_box(arena.merged_data());
        views.clear();
        for i in 0..M {
            views.push(y.view0(i).unwrap());
        }
        for v in &views {
            std::hint::black_box(v.data());
        }
    };
    let arena_m = b.run(&format!("round/{name}/arena  m={M}"), &mut arena_round);

    // --- steady-state allocation count ---------------------------------
    arena_round(); // ensure scratch capacity is warm
    let rounds = 256u64;
    let before = counting_alloc::allocations();
    for _ in 0..rounds {
        arena_round();
    }
    let allocs = counting_alloc::allocations() - before;
    let per_round = allocs / rounds;

    // --- padded steady state: absent slots skip the pad copy -----------
    // half the fleet is idle every round; after the first round their
    // windows are zero and stay zero, so pack_with skips the
    // memset-equivalent entirely (the occupancy-tracking optimization)
    let mut padded_arena = RoundArena::new(layout, M, request_shape)?;
    let mut padded_round = |arena: &mut RoundArena| {
        let get = |i: usize| if i % 2 == 0 { Some(&xs[i]) } else { None };
        arena.pack_with(&get).unwrap();
        std::hint::black_box(arena.merged_data());
    };
    padded_round(&mut padded_arena); // warm: absent windows zeroed once
    let writes_before = padded_arena.pad_writes();
    let padded = b.run(&format!("round/{name}/arena-padded m={M}"), || {
        padded_round(&mut padded_arena)
    });
    assert_eq!(
        padded_arena.pad_writes(),
        writes_before,
        "steady-state padded rounds must not re-copy the zero pad block"
    );

    println!(
        "round/{name}: {} allocations across {} steady-state rounds",
        allocs, rounds
    );
    println!(
        "round/{name}: legacy {:.3e}s  arena {:.3e}s  padded {:.3e}s  speedup {:.2}x\n",
        legacy.mean,
        arena_m.mean,
        padded.mean,
        legacy.mean / arena_m.mean
    );
    Ok((legacy.mean, arena_m.mean, padded.mean, per_round))
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new();
    b.config = Config { warmup_s: 0.2, samples: 15, min_sample_s: 0.005 };
    let mut rng = Rng::new(0xA12E);

    println!("# round_pipeline: zero-copy data plane vs seed path (m={M})\n");

    // mini-model-shaped payloads: CNN fleet packs on channel, sequence
    // fleet packs on batch
    let (ch_legacy, ch_arena, ch_padded, ch_allocs) =
        bench_layout(&mut b, Layout::Channel, &[1, 3, 16, 16], &mut rng)?;
    let (ba_legacy, ba_arena, ba_padded, ba_allocs) =
        bench_layout(&mut b, Layout::Batch, &[1, 64], &mut rng)?;

    // --- strategy dispatch: per-round spawn vs persistent pool ---------
    let xs: Vec<Tensor> = (0..M).map(|_| Tensor::randn(&[1, 3, 16, 16], &mut rng)).collect();
    let spawn = b.run("dispatch/thread-scope spawn per round", || {
        let results: Vec<f32> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..M)
                .map(|i| {
                    let x = &xs[i];
                    scope.spawn(move || x.data().iter().sum::<f32>())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        std::hint::black_box(&results);
    });
    let pool = WorkerPool::new(M);
    let pooled = b.run("dispatch/persistent worker pool", || {
        let results = pool
            .run_chunked(M, M, |i| Ok(std::hint::black_box(xs[i].data().iter().sum::<f32>())))
            .unwrap();
        std::hint::black_box(&results);
    });
    println!(
        "\ndispatch: spawn {:.3e}s  pool {:.3e}s  speedup {:.2}x",
        spawn.mean,
        pooled.mean,
        spawn.mean / pooled.mean
    );

    // --- BENCH_round_pipeline.json report ------------------------------
    let mut layouts = BTreeMap::new();
    for (name, legacy, arena, padded, allocs) in [
        ("channel", ch_legacy, ch_arena, ch_padded, ch_allocs),
        ("batch", ba_legacy, ba_arena, ba_padded, ba_allocs),
    ] {
        let mut o = BTreeMap::new();
        o.insert("legacy_s".to_string(), num(legacy));
        o.insert("arena_s".to_string(), num(arena));
        o.insert("arena_padded_s".to_string(), num(padded));
        o.insert("legacy_rounds_per_sec".to_string(), num(1.0 / legacy));
        o.insert("arena_rounds_per_sec".to_string(), num(1.0 / arena));
        o.insert("speedup".to_string(), num(legacy / arena));
        o.insert(
            "steady_state_allocs_per_round".to_string(),
            num(allocs as f64),
        );
        layouts.insert(name.to_string(), Json::Obj(o));
    }
    let mut dispatch = BTreeMap::new();
    dispatch.insert("thread_scope_s".to_string(), num(spawn.mean));
    dispatch.insert("worker_pool_s".to_string(), num(pooled.mean));
    dispatch.insert("speedup".to_string(), num(spawn.mean / pooled.mean));
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("round_pipeline".to_string()));
    root.insert("m".to_string(), num(M as f64));
    root.insert("layouts".to_string(), Json::Obj(layouts));
    root.insert("dispatch".to_string(), Json::Obj(dispatch));

    let path = "BENCH_round_pipeline.json";
    std::fs::write(path, Json::Obj(root).dump())?;
    println!("report written to {path}");

    // acceptance gates, checked AFTER the report is on disk so a noisy
    // run still leaves its numbers behind for inspection
    let mut failures = Vec::new();
    for (name, legacy, arena, allocs) in [
        ("channel", ch_legacy, ch_arena, ch_allocs),
        ("batch", ba_legacy, ba_arena, ba_allocs),
    ] {
        if allocs != 0 {
            failures.push(format!(
                "{name}: steady-state arena round allocated ({allocs} allocs/round, want 0)"
            ));
        }
        let speedup = legacy / arena;
        if speedup < 2.0 {
            failures.push(format!(
                "{name}: arena speedup {speedup:.2}x over the legacy pack path (want >= 2x)"
            ));
        }
    }
    assert!(failures.is_empty(), "round_pipeline gates failed:\n  {}", failures.join("\n  "));
    Ok(())
}
