//! Bench: overload robustness — the saturation study behind ADR-007.
//!
//! Sweeps offered load across multiples of the serving stack's
//! estimated capacity and maps **served goodput** and **served p99**
//! against offered load, locating the knee. With admission control
//! (typed `Reject{Shed}` when a lane's projected queue wait exceeds its
//! SLO) the served-goodput curve must stay flat past the knee instead
//! of collapsing into queue bloat: every slot the server spends goes to
//! a request that can still meet its deadline.
//!
//! Parts:
//! 1. **Poisson sweep** — offered load at {0.5, 0.75, 1.0, 1.5, 2.0}x
//!    estimated capacity through the full frame -> bridge -> QoS ->
//!    response path. Gates (full mode): goodput at 2x overload >= 0.9x
//!    the pre-knee plateau, and served p99 <= 1.5x SLO (admission
//!    projects wait <= SLO at admit time; the adaptive-eps tail bound
//!    covers the rest).
//! 2. **Bursty + skewed passes** at 2x — the same stack under on/off
//!    modulation and 90/10 lane skew, demonstrating per-lane shed
//!    attribution (`IngressStats::lane_reject_rows`).
//!
//! Every mode (smoke included) gates the exactly-one-outcome contract:
//! each submitted request gets a response XOR one typed reject.
//! Results go to `BENCH_overload.json`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch, serve_conn, ChanTransport, Frame, IngressBridge, IngressStats, LaneQos, LoadGen,
    RejectCode, TrafficShape, Transport, TransportRx, TransportTx,
};
use netfuse::util::json::Json;

/// models per lane
const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
/// modeled device time per round — capacity is M / ROUND_COST per lane
/// round, but one dispatch thread serves both lanes, so the stack-wide
/// estimate is M / ROUND_COST (rounds are serialized on the thread).
const ROUND_COST: Duration = Duration::from_micros(200);
/// both lanes' SLO: far above one round, well below a bloated queue, so
/// the shed threshold sits at a backlog of ~SLO/ROUND_COST * M requests
const SLO: Duration = Duration::from_millis(10);
const PRODUCERS: usize = 2;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn echo(name: &str) -> EchoExecutor {
    EchoExecutor::new(name, M, &[4], ROUND_COST)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::Sequential,
        queue_cap: 512,
        max_wait: Duration::ZERO,
    }
}

/// Client-side outcome tally for one run.
#[derive(Default, Clone, Copy)]
struct Outcomes {
    ok: u64,
    shed: u64,
    busy: u64,
    other_reject: u64,
}

impl Outcomes {
    fn total(&self) -> u64 {
        self.ok + self.shed + self.busy + self.other_reject
    }
}

struct Run {
    sent: u64,
    out: Outcomes,
    stats: IngressStats,
    elapsed: f64,
    /// served p99 (seconds) and SLO violations per lane
    lanes: Vec<(u64, f64, u64)>,
}

/// One open-loop pass: `shape` arrivals split across [`PRODUCERS`]
/// in-proc connections into one QoS lane per `skew` entry, every
/// outcome frame tallied on the client side. The saturation sweep uses
/// ONE lane so the admission projection (per-lane backlog x round p99)
/// matches the actual service rate — the dispatch thread is not shared;
/// the skew pass uses two to exercise per-lane shed attribution.
fn run_shape(shape: TrafficShape, skew: &[(usize, f64)], horizon: Duration, seed: u64) -> Result<Run> {
    let fleets: Vec<EchoExecutor> = (0..skew.len()).map(|i| echo(&format!("lane-{i}"))).collect();
    let mut multi = MultiServer::new();
    for f in &fleets {
        multi.add_lane_qos(f, lane_config(), LaneQos::new(1, SLO));
    }
    let bridge = IngressBridge::new(1024);

    let shards = LoadGen::new(shape, skew, seed)?.shards(PRODUCERS);

    let t0 = Instant::now();
    let (stats, sent, out) = std::thread::scope(|s| -> Result<(IngressStats, u64, Outcomes)> {
        let bridge_ref = &bridge;
        let multi_ref = &mut multi;
        let dispatch = s.spawn(move || run_dispatch(multi_ref, bridge_ref));

        let mut conns = Vec::new();
        let mut receivers = Vec::new();
        let mut senders = Vec::new();
        for shard in shards {
            let (client, server_end) = ChanTransport::pair();
            let conn = serve_conn(bridge.clone(), Box::new(server_end))
                .expect("in-proc serve_conn cannot fail");
            conns.push(conn);
            let (mut tx, mut rx) = (Box::new(client) as Box<dyn Transport>)
                .split()
                .expect("in-proc split cannot fail");
            receivers.push(s.spawn(move || {
                let mut out = Outcomes::default();
                loop {
                    match rx.recv() {
                        Ok(Some(Frame::Response { .. })) => out.ok += 1,
                        Ok(Some(Frame::Reject { code, .. })) => match code {
                            RejectCode::Shed => out.shed += 1,
                            RejectCode::Busy => out.busy += 1,
                            _ => out.other_reject += 1,
                        },
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return out,
                    }
                }
            }));
            senders.push(s.spawn(move || {
                let sent = shard.drive(horizon, |a| {
                    let _ = tx.send(&Frame::Request {
                        id: a.id,
                        lane: a.lane as u32,
                        model_idx: a.model_idx as u32,
                        shape: INPUT_SHAPE.to_vec(),
                        data: vec![0.0; 4],
                    });
                });
                let _ = tx.send(&Frame::Eos);
                sent
            }));
        }

        let mut sent = 0u64;
        for t in senders {
            sent += t.join().unwrap();
        }
        bridge.close();
        let stats_res = dispatch.join().unwrap();
        for c in conns {
            c.shutdown();
        }
        let mut out = Outcomes::default();
        for r in receivers {
            let o = r.join().unwrap();
            out.ok += o.ok;
            out.shed += o.shed;
            out.busy += o.busy;
            out.other_reject += o.other_reject;
        }
        Ok((stats_res?, sent, out))
    })?;
    let elapsed = t0.elapsed().as_secs_f64();

    let lanes = (0..multi.lanes())
        .map(|i| {
            let met = &multi.lane(i).metrics;
            (met.completed_requests, met.request_latency.p99(), met.slo_violations)
        })
        .collect();
    Ok(Run { sent, out, stats, elapsed, lanes })
}

fn sweep_point_json(mult: f64, rate: f64, r: &Run) -> Json {
    let mut o = BTreeMap::new();
    o.insert("offered_mult".to_string(), num(mult));
    o.insert("offered_rps".to_string(), num(rate));
    o.insert("sent".to_string(), num(r.sent as f64));
    o.insert("served".to_string(), num(r.out.ok as f64));
    o.insert("shed".to_string(), num(r.out.shed as f64));
    o.insert("busy".to_string(), num(r.out.busy as f64));
    o.insert("goodput_rps".to_string(), num(r.out.ok as f64 / r.elapsed.max(1e-9)));
    let p99 = r.lanes.iter().map(|&(_, p, _)| p).fold(0.0f64, f64::max);
    let viol: u64 = r.lanes.iter().map(|&(_, _, v)| v).sum();
    o.insert("served_p99_s".to_string(), if p99.is_finite() { num(p99) } else { Json::Null });
    o.insert("slo_violations".to_string(), num(viol as f64));
    Json::Obj(o)
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("# overload: saturation sweep + shedding (ADR-007){}\n", if smoke { " (SMOKE)" } else { "" });

    // one dispatch thread serves one round (M requests) per ROUND_COST
    let capacity = M as f64 / ROUND_COST.as_secs_f64();
    let solo = [(M, 1.0)];
    let (multiples, horizon): (&[f64], Duration) = if smoke {
        (&[0.5, 1.0, 2.0], Duration::from_millis(150))
    } else {
        (&[0.5, 0.75, 1.0, 1.5, 2.0], Duration::from_secs(1))
    };

    // --- part 1: Poisson sweep across offered-load multiples -----------
    let mut points: Vec<(f64, Run)> = Vec::new();
    for (i, &mult) in multiples.iter().enumerate() {
        let rate = capacity * mult;
        let run = run_shape(
            TrafficShape::Poisson { rate },
            &solo,
            horizon,
            0x0DE55 + i as u64,
        )?;
        let viol: u64 = run.lanes.iter().map(|&(_, _, v)| v).sum();
        println!(
            "poisson {mult:>4.2}x ({rate:>6.0} rps): sent {:>5} -> {:>5} served \
             + {:>4} shed + {:>3} busy  goodput {:>6.0} rps  viol {viol}",
            run.sent,
            run.out.ok,
            run.out.shed,
            run.out.busy,
            run.out.ok as f64 / run.elapsed,
        );
        points.push((mult, run));
    }

    // knee: the first multiple where served goodput stops tracking the
    // offered rate (served / offered < 0.95)
    let knee = points
        .iter()
        .find(|(_, r)| (r.out.ok as f64) < 0.95 * r.sent as f64)
        .map(|&(m, _)| m);
    println!("knee located at {:?}x offered load", knee);

    // --- part 2: bursty + skewed passes at the top multiple ------------
    let top = *multiples.last().unwrap();
    let bursty = run_shape(
        TrafficShape::Bursty {
            rate: capacity * top * 2.0, // 2x during on-windows, 50% duty
            on: Duration::from_millis(20),
            off: Duration::from_millis(20),
        },
        &solo,
        horizon,
        0xB0257,
    )?;
    println!(
        "bursty  {top:.1}x avg: sent {} -> {} served + {} shed + {} busy",
        bursty.sent, bursty.out.ok, bursty.out.shed, bursty.out.busy
    );
    let skewed = run_shape(
        TrafficShape::Poisson { rate: capacity * top },
        &[(M, 9.0), (M, 1.0)],
        horizon,
        0x53E3D,
    )?;
    let rows = skewed.stats.lane_reject_rows();
    println!(
        "skewed  {top:.1}x 90/10: sent {} -> {} served + {} shed; per-lane rejects {:?}",
        skewed.sent, skewed.out.ok, skewed.out.shed, rows
    );

    // --- BENCH_overload.json --------------------------------------------
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("overload".to_string()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("capacity_est_rps".to_string(), num(capacity));
    root.insert("slo_s".to_string(), num(SLO.as_secs_f64()));
    root.insert("round_cost_s".to_string(), num(ROUND_COST.as_secs_f64()));
    root.insert(
        "sweep".to_string(),
        Json::Arr(points.iter().map(|(m, r)| sweep_point_json(*m, capacity * m, r)).collect()),
    );
    root.insert("knee_mult".to_string(), knee.map(num).unwrap_or(Json::Null));
    root.insert("bursty".to_string(), sweep_point_json(top, capacity * top, &bursty));
    root.insert("skewed".to_string(), sweep_point_json(top, capacity * top, &skewed));
    root.insert(
        "skewed_lane_rejects".to_string(),
        Json::Arr(
            rows.iter()
                .map(|(l, r)| {
                    let mut o = BTreeMap::new();
                    o.insert("lane".to_string(), num(*l as f64));
                    o.insert("busy".to_string(), num(r.busy as f64));
                    o.insert("shed".to_string(), num(r.shed as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let path = "BENCH_overload.json";
    std::fs::write(path, Json::Obj(root).dump())?;
    println!("report written to {path}");

    // --- gates (report written first so failing runs leave numbers) ----
    // every mode: exactly one outcome frame per submitted request
    for (m, r) in points.iter().chain([(top, bursty), (top, skewed)].iter()) {
        ensure!(
            r.out.total() == r.sent,
            "at {m}x: {} outcomes ({} ok + {} shed + {} busy + {} other) != {} sent \
             — the one-outcome-per-submission contract broke",
            r.out.total(),
            r.out.ok,
            r.out.shed,
            r.out.busy,
            r.out.other_reject,
            r.sent
        );
        // shed attribution: dispatch-side counters match the wire
        ensure!(
            r.stats.shed == r.out.shed,
            "at {m}x: stats.shed {} != {} Shed frames on the wire",
            r.stats.shed,
            r.out.shed
        );
        let row_shed: u64 = r.stats.lane_reject_rows().iter().map(|(_, lr)| lr.shed).sum();
        ensure!(
            row_shed == r.stats.shed,
            "per-lane shed rows sum to {row_shed}, scalar says {}",
            r.stats.shed
        );
    }

    // timing gates only in full runs (smoke must not flake on CI noise)
    if !smoke {
        let plateau = points
            .iter()
            .filter(|(m, _)| *m <= 1.0)
            .map(|(_, r)| r.out.ok as f64 / r.elapsed)
            .fold(0.0f64, f64::max);
        let (top_mult, top_run) = points.last().unwrap();
        let top_goodput = top_run.out.ok as f64 / top_run.elapsed;
        ensure!(
            top_goodput >= 0.9 * plateau,
            "goodput at {top_mult}x overload ({top_goodput:.0} rps) fell below 0.9x \
             the pre-knee plateau ({plateau:.0} rps): shedding is not protecting \
             served throughput"
        );
        ensure!(
            top_run.out.shed > 0,
            "a {top_mult}x overload run must shed — admission control never engaged"
        );
        // served tail: admission projects wait <= SLO at admit time and
        // the adaptive eps is clamped to slo/2, so served p99 must stay
        // within 1.5x SLO even past the knee
        let p99 = top_run.lanes.iter().map(|&(_, p, _)| p).fold(0.0f64, f64::max);
        ensure!(
            p99 <= 1.5 * SLO.as_secs_f64(),
            "served p99 {:.1}ms at {top_mult}x exceeds the 1.5x SLO bound ({:.0}ms): \
             shedding admitted doomed requests",
            p99 * 1e3,
            1.5 * SLO.as_secs_f64() * 1e3
        );
    }
    println!("\noverload gates passed");
    Ok(())
}
