//! Bench: elastic topology churn under open-loop load (ADR-005).
//!
//! One serving topology — a 2-lane coalesce group + a standalone lane,
//! plus a spare partition — is driven by an open-loop producer at a
//! fixed pace while (in the churn run) a controller thread cycles
//! add-lane → hot-swap → remove-lane through `TopologyController`. The
//! control plane's balance heuristic lands every transient lane on the
//! spare partition, so the producer's latencies measure exactly what
//! ADR-005 promises: control-plane churn on a sibling partition must
//! not disturb steady traffic.
//!
//! Gates:
//! - **every mode**: every submission (producer + controller bursts)
//!   gets exactly one outcome frame; zero rejects; every response is
//!   byte-exact for its (id, model) seed — swap bursts offset by
//!   exactly `tag * SWAP_SCALE` — so nothing is ever lost, misrouted,
//!   or served by the wrong weights; merged rounds keep flowing.
//! - **full mode only** (CI runs `--smoke`): producer p99 latency in
//!   the churn run <= 2x the churn-free steady-state p99.
//!
//! All in-scope failure paths return errors (no asserts before the
//! bridge closes), so a broken run fails instead of deadlocking the
//! dispatch thread; verification runs post-join.
//!
//! Results go to `BENCH_elastic_churn.json`.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use netfuse::coordinator::control::{ControlPlane, TopologyController};
use netfuse::coordinator::mock::{EchoExecutor, SWAP_SCALE};
use netfuse::coordinator::multi::{GroupSpec, LaneSpec, ParallelDispatcher};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch_elastic, Envelope, Frame, FrameQueue, IngressBridge, IngressStats, LaneQos,
};
use netfuse::util::bench::report::BenchReport;
use netfuse::util::json::Json;
use netfuse::util::shard::Sharded;

/// The shared test scaffolding (seeded request builder) — outcome
/// verification uses the same payload-seeding scheme as the test
/// suites.
#[path = "../rust/tests/common/mod.rs"]
mod common;

/// models per lane (the group executor runs 2 * M slots)
const M: usize = 2;
const INNER: [usize; 1] = [4];
/// modeled device time per round — small, so steady-state latency is
/// dominated by dispatch, and any churn-induced stall shows up
const ROUND_COST: Duration = Duration::from_micros(100);
/// modeled weight-upload time per hot-swap (the bounded pause)
const SWAP_COST: Duration = Duration::from_micros(200);
const FAR: Duration = Duration::from_secs(3600);
/// requests per controller burst (two bursts per cycle: factory
/// weights, then swapped weights)
const BURST: usize = 8;
/// transient-lane burst ids start here — disjoint from producer ids
const BURST_ID0: u64 = 1_000_000;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 8192,
        max_wait: Duration::ZERO,
    }
}

/// The whole-run executors plus one pre-built transient executor per
/// churn cycle (the dispatcher borrows them, so they must outlive it).
struct Execs {
    bert0: EchoExecutor,
    bert1: EchoExecutor,
    group: EchoExecutor,
    solo: EchoExecutor,
    churners: Vec<EchoExecutor>,
}

impl Execs {
    fn new(cycles: usize) -> Execs {
        Execs {
            bert0: EchoExecutor::new("bert", M, &INNER, ROUND_COST),
            bert1: EchoExecutor::new("bert", M, &INNER, ROUND_COST),
            group: EchoExecutor::new("bert", 2 * M, &INNER, ROUND_COST),
            solo: EchoExecutor::new("solo", M, &INNER, ROUND_COST),
            churners: (0..cycles)
                .map(|c| {
                    EchoExecutor::new(&format!("churn{c}"), M, &INNER, ROUND_COST)
                        .with_swap_cost(SWAP_COST)
                })
                .collect(),
        }
    }
}

fn seeded_at(id: u64, model: usize, j: usize) -> f32 {
    id as f32 * 1000.0 + model as f32 * 10.0 + j as f32
}

/// Check one response against its (id, model) seed plus a weight
/// offset.
fn check_exact(id: u64, model: usize, offset: f32, data: &[f32]) -> Result<()> {
    ensure!(data.len() == INNER[0], "id {id}: bad payload length {}", data.len());
    for (j, &x) in data.iter().enumerate() {
        ensure!(
            x == seeded_at(id, model, j) + offset,
            "id {id} misrouted or served by the wrong weights \
             (byte {j}: got {x}, want {})",
            seeded_at(id, model, j) + offset
        );
    }
    Ok(())
}

fn p99(sorted: &[f64]) -> f64 {
    sorted[(sorted.len() as f64 * 0.99) as usize - 1]
}

struct RunOut {
    p50: f64,
    p99: f64,
    served: usize,
    burst_served: usize,
    swap_pause_max: f64,
    stats: IngressStats,
    epochs: u64,
}

/// One serving run: `load` paced producer requests over the three
/// whole-run lanes; when `churn` is set, a controller thread cycles
/// add → burst → swap → burst → remove through every transient
/// executor concurrently.
fn run(execs: &Execs, load: usize, pace: Duration, churn: bool) -> Result<RunOut> {
    let mut d = ParallelDispatcher::new(
        vec![
            LaneSpec::new(&execs.bert0, lane_config(), LaneQos::new(1, FAR)),
            LaneSpec::new(&execs.bert1, lane_config(), LaneQos::new(1, FAR)),
            LaneSpec::new(&execs.solo, lane_config(), LaneQos::new(1, FAR)),
        ],
        vec![GroupSpec::new(&execs.group, &[0, 1])],
    )?;
    d.add_spare_part(); // where the balance heuristic lands every add
    let plane = Arc::new(ControlPlane::for_dispatcher(&d));
    let ctl = TopologyController::new(d.topology_handle(), Arc::clone(&plane));
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(d.parts() + 1));
    let bridge = IngressBridge::new(load + 4 * BURST * execs.churners.len() + 16);
    let epoch0 = ctl.epoch();

    // producer-side records: submit time per id, (frame, arrival) pairs
    let mut submitted: HashMap<u64, (usize, Instant)> = HashMap::new();
    let mut arrived: Vec<(Frame, Instant)> = Vec::with_capacity(load);
    let mut ctl_out: Result<(Vec<Frame>, f64)> = Ok((Vec::new(), 0.0));
    let run_out: Result<()> = std::thread::scope(|s| {
        let runner = s.spawn(|| run_dispatch_elastic(&mut d, &bridge, 4096, &stats, &plane));

        // churn controller: every transient lane lives on the spare
        // partition (it is always the least-mapped), gets a factory
        // burst, a hot-swap, a swapped burst, and a clean removal
        let controller = churn.then(|| {
            let ctl = &ctl;
            let bridge = &bridge;
            let churners = &execs.churners;
            s.spawn(move || -> Result<(Vec<Frame>, f64)> {
                let reply = FrameQueue::new();
                let mut frames = Vec::new();
                let mut pause_max = 0.0f64;
                let mut id = BURST_ID0;
                let wait = Duration::from_secs(10);
                for (c, exec) in churners.iter().enumerate() {
                    let spec = LaneSpec::new(exec, lane_config(), LaneQos::new(1, FAR));
                    let (global, ticket) = ctl.add_lane(spec)?;
                    ticket.wait(wait)?;
                    for phase in 0..2u64 {
                        for i in 0..BURST {
                            let env = Envelope {
                                lane: global,
                                client_id: id,
                                req: common::seeded_request(id, i % M, &INNER),
                                reply: reply.clone(),
                            };
                            if bridge.submit(env).is_err() {
                                bail!("burst submit refused (bridge sized for the run)");
                            }
                            id += 1;
                        }
                        // the burst must be fully answered before the
                        // swap/remove so neither can strand it
                        let deadline = Instant::now() + wait;
                        let mut got = 0;
                        while got < BURST {
                            if let Some(f) = reply.try_pop() {
                                frames.push(f);
                                got += 1;
                                continue;
                            }
                            if Instant::now() >= deadline {
                                bail!("transient-lane burst stalled ({got}/{BURST})");
                            }
                            std::thread::sleep(Duration::from_micros(50));
                        }
                        if phase == 0 {
                            let pause = ctl.swap_model(global, c as u64 + 1)?.wait(wait)?;
                            pause_max = pause_max.max(pause.as_secs_f64());
                        }
                    }
                    ctl.remove_lane(global)?.wait(wait)?;
                }
                Ok((frames, pause_max))
            })
        });

        // open-loop producer: paced submissions over the whole-run
        // lanes regardless of response progress, draining replies
        // opportunistically so arrival timestamps stay honest
        let reply = FrameQueue::new();
        let mut drain = |arrived: &mut Vec<(Frame, Instant)>| {
            while let Some(f) = reply.try_pop() {
                arrived.push((f, Instant::now()));
            }
        };
        for i in 0..load {
            let id = i as u64;
            let env = Envelope {
                lane: i % 3,
                client_id: id,
                req: common::seeded_request(id, i % M, &INNER),
                reply: reply.clone(),
            };
            if bridge.submit(env).is_err() {
                bridge.close(); // let the runner drain out before we bail
                bail!("producer submit refused (bridge sized for the run)");
            }
            submitted.insert(id, (i % M, Instant::now()));
            drain(&mut arrived);
            std::thread::sleep(pace);
        }

        if let Some(t) = controller {
            ctl_out = t.join().expect("controller panicked");
        }
        bridge.close(); // runner drains everything queued, then exits

        // keep timestamping arrivals while the tail drains
        let deadline = Instant::now() + Duration::from_secs(30);
        while !runner.is_finished() && Instant::now() < deadline {
            drain(&mut arrived);
            std::thread::sleep(Duration::from_micros(50));
        }
        drain(&mut arrived);
        runner.join().expect("dispatch runner panicked")
    });
    run_out?;
    let (burst_frames, swap_pause_max) = ctl_out?;

    // ---- post-join verification: nothing lost, nothing misrouted ----
    let mut lat = Vec::with_capacity(load);
    for (f, at) in &arrived {
        match f {
            Frame::Response { id, model_idx, data, .. } => {
                let Some((model, t0)) = submitted.remove(id) else {
                    bail!("id {id}: response never submitted, or served twice");
                };
                ensure!(*model_idx as usize == model, "id {id}: wrong model");
                check_exact(*id, model, 0.0, data)?;
                lat.push((*at - t0).as_secs_f64());
            }
            other => bail!("steady lanes must never reject: {other:?}"),
        }
    }
    ensure!(
        submitted.is_empty(),
        "{} producer requests lost under churn",
        submitted.len()
    );
    let mut burst_seen: HashMap<u64, ()> = HashMap::new();
    for f in &burst_frames {
        match f {
            Frame::Response { id, model_idx, data, .. } => {
                ensure!(*id >= BURST_ID0, "burst reply with a producer id {id}");
                ensure!(burst_seen.insert(*id, ()).is_none(), "id {id} served twice");
                // ids encode (cycle, phase, i): recover the expected
                // model and weight offset
                let k = (id - BURST_ID0) as usize;
                let (cycle, phase, i) = (k / (2 * BURST), k / BURST % 2, k % BURST);
                ensure!(*model_idx as usize == i % M, "burst id {id}: wrong model");
                let offset = if phase == 1 { (cycle as u64 + 1) as f32 * SWAP_SCALE } else { 0.0 };
                check_exact(*id, i % M, offset, data)?;
            }
            other => bail!("transient lanes must never reject mid-life: {other:?}"),
        }
    }

    ensure!(!lat.is_empty(), "no producer latencies recorded");
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(RunOut {
        p50: lat[lat.len() / 2],
        p99: p99(&lat),
        served: lat.len(),
        burst_served: burst_frames.len(),
        swap_pause_max,
        epochs: ctl.epoch() - epoch0,
        stats: stats.read(),
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "# elastic_churn: control-plane churn next to open-loop traffic{}\n",
        if smoke { " (SMOKE)" } else { "" }
    );

    let load = if smoke { 400 } else { 4000 };
    let pace = Duration::from_micros(if smoke { 200 } else { 400 });
    let cycles = if smoke { 2 } else { 10 };

    let steady_execs = Execs::new(0);
    let steady = run(&steady_execs, load, pace, false)?;
    let churn_execs = Execs::new(cycles);
    let churned = run(&churn_execs, load, pace, true)?;
    let inflation = churned.p99 / steady.p99.max(1e-9);

    for (name, r) in [("steady", &steady), ("churn ", &churned)] {
        println!(
            "{name}: {} served, p50 {:.0}us p99 {:.0}us | {} burst reqs, \
             {} ctrl ops, {} epochs, {} merged rounds",
            r.served,
            r.p50 * 1e6,
            r.p99 * 1e6,
            r.burst_served,
            r.stats.ctrl_ops,
            r.epochs,
            r.stats.coalesced_rounds,
        );
    }
    println!(
        "p99 inflation under churn: {inflation:.2}x (max swap pause {:.0}us)\n",
        churned.swap_pause_max * 1e6
    );

    let obj = |r: &RunOut| {
        let mut o = BTreeMap::new();
        o.insert("served".to_string(), num(r.served as f64));
        o.insert("burst_served".to_string(), num(r.burst_served as f64));
        o.insert("p50_s".to_string(), num(r.p50));
        o.insert("p99_s".to_string(), num(r.p99));
        o.insert("ctrl_ops".to_string(), num(r.stats.ctrl_ops as f64));
        o.insert("epochs".to_string(), num(r.epochs as f64));
        o.insert("merged_rounds".to_string(), num(r.stats.coalesced_rounds as f64));
        o.insert("responses".to_string(), num(r.stats.responses as f64));
        Json::Obj(o)
    };
    let mut rep = BenchReport::new("elastic_churn", smoke);
    rep.num("load", load as f64)
        .num("pace_us", pace.as_secs_f64() * 1e6)
        .num("churn_cycles", cycles as f64)
        .num("p99_inflation", inflation)
        .num("swap_pause_max_s", churned.swap_pause_max)
        .set("steady", obj(&steady))
        .set("churn", obj(&churned))
        .ns_per_slot("steady_p99", steady.p99 * 1e9)
        .ns_per_slot("churn_p99", churned.p99 * 1e9);
    rep.write()?;

    // correctness gates run in every mode (written AFTER the report so
    // a failing run still leaves its numbers behind); run() already
    // enforced exactly-one byte-exact outcome per submission
    assert_eq!(steady.served, load, "steady run lost requests");
    assert_eq!(churned.served, load, "churn run lost requests");
    assert_eq!(churned.burst_served, cycles * 2 * BURST, "transient bursts lost requests");
    assert_eq!(
        churned.stats.ctrl_ops as usize,
        cycles * 3,
        "every add/swap/remove must be applied"
    );
    assert!(
        churned.stats.coalesced_rounds > 0,
        "the group must keep merging rounds during churn"
    );
    assert_eq!(steady.stats.ctrl_ops, 0);
    assert!(churned.epochs >= cycles as u64 * 3, "epoch must advance with every op");
    // the p99 gate is full-mode only: smoke runs are too short for a
    // stable tail estimate on shared CI runners
    if !smoke {
        assert!(
            inflation <= 2.0,
            "churn inflated steady-traffic p99 by {inflation:.2}x (> 2x): \
             sibling-partition churn is supposed to be non-disruptive"
        );
    }
    Ok(())
}
