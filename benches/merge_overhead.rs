//! Bench: §4 merge overhead — Algorithm 1 + weight stacking wall time
//! per model family and instance count. The paper reports <= 600 ms for
//! 32 ResNeXt-50 instances (amortized offline; sub-linear in M).

use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("{}", figures::merge_overhead(&rt, &FigOpts::default())?);
    Ok(())
}
