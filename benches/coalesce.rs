//! Bench: cross-fleet round coalescing — served throughput and routing
//! fidelity of merged rounds vs lane-by-lane dispatch.
//!
//! Three parts, all offline (mock `RoundExecutor` lanes with a modeled
//! per-round device cost — ONE merged execution costs one round, which
//! is exactly the launch-amortization NETFUSE banks on):
//!
//! 1. **Saturated drive** — two same-family lanes kept fully loaded,
//!    dispatched closed-loop with and without a coalesce group. The
//!    merged run serves both lanes per device round, so the throughput
//!    ratio must be >= 1.3x (it is ~2x by construction). Deterministic
//!    (the sleep dominates both runs identically), so the gate runs in
//!    every mode including `--smoke` on CI.
//! 2. **Routing oracle** — the same seeded arrival sequence (ids, lanes,
//!    models, payload bytes derived from the id) is served coalesced and
//!    uncoalesced with zero-cost executors; the per-lane FIFO response
//!    streams are diffed byte-for-byte. Gate (every mode): **zero
//!    diffs** — the `SlotMap` scatter may never misroute, reorder, or
//!    corrupt a response.
//! 3. **Open loop** — producers drive Poisson arrivals through in-proc
//!    transports, `serve_conn`, the bounded bridge, and one
//!    `run_dispatch` thread, at a rate above one-round-per-lane capacity
//!    but below merged capacity. Full runs gate the served-throughput
//!    ratio >= 1.3x (smoke keeps the exactly-one-outcome-per-arrival
//!    invariant only, so CI never flakes on timing).
//!
//! Results go to `BENCH_coalesce.json`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::{Request, StrategyKind};
use netfuse::ingress::{
    run_dispatch, serve_conn, ChanTransport, Frame, IngressBridge, IngressStats, LaneQos, LoadGen,
    TrafficShape, Transport, TransportRx, TransportTx,
};
use netfuse::tensor::Tensor;
use netfuse::util::json::Json;

/// The shared test scaffolding (seeded request builder, echo wiring) —
/// the oracle diff below must use the SAME payload-seeding scheme as
/// the coalesce property suite, so both consume one definition.
#[path = "../rust/tests/common/mod.rs"]
mod common;

/// models per lane (the group executor runs 2 * M slots)
const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
/// modeled device time per round — solo or merged, ONE launch. 1ms
/// keeps one-round-per-lane capacity (~2k req/s over 2 models) far
/// below the open-loop offered rate, so the solo baseline saturates
/// decisively and the >= 1.3x gate is sleep-dominated, not noise.
const ROUND_COST: Duration = Duration::from_millis(1);
const FAR: Duration = Duration::from_secs(3600);

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// All lanes and group executors serve one model family.
fn echo(m: usize, round_cost: Duration) -> EchoExecutor {
    common::echo("family", m, round_cost)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 256,
        max_wait: Duration::from_millis(3),
    }
}

/// Deterministic payload derived from (id, model) so the oracle can
/// diff response bytes (the shared seeding scheme, at this bench's
/// request shape).
fn seeded_request(id: u64, model_idx: usize) -> Request {
    common::seeded_request(id, model_idx, &INPUT_SHAPE[1..])
}

// ---------------------------------------------------------------------------
// part 1: saturated closed-loop drive (deterministic ratio gate)
// ---------------------------------------------------------------------------

fn saturated(coalesced: bool, rounds: usize) -> Result<(f64, u64, u64)> {
    let a = echo(M, ROUND_COST);
    let b = echo(M, ROUND_COST);
    let g = echo(2 * M, ROUND_COST);
    let mut multi = MultiServer::new();
    let cfg = ServerConfig { max_wait: Duration::ZERO, ..lane_config() };
    let la = multi.add_lane_qos(&a, cfg.clone(), LaneQos::new(1, FAR));
    let lb = multi.add_lane_qos(&b, cfg, LaneQos::new(1, FAR));
    let group = if coalesced {
        Some(multi.add_coalesce_group(&g, &[la, lb])?)
    } else {
        None
    };

    let mut id = 0u64;
    let mut buf = Vec::new();
    let mut served = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        // one full round of work per lane, then dispatch to empty
        for lane in [la, lb] {
            for model in 0..M {
                multi.offer(lane, Request::new(id, model, Tensor::zeros(&INPUT_SHAPE)))?;
                id += 1;
            }
        }
        while let Some(d) = multi.dispatch_next(&mut buf)? {
            served += d.responses as u64;
            buf.clear();
        }
    }
    let rps = served as f64 / t0.elapsed().as_secs_f64();
    let merged = group.map_or(0, |g| multi.group_stats(g).rounds);
    Ok((rps, served, merged))
}

// ---------------------------------------------------------------------------
// part 2: routing oracle (zero-cost executors, byte-exact diff)
// ---------------------------------------------------------------------------

use common::{collect_streams, Streams};

fn oracle_run(coalesced: bool, arrivals: &[(usize, usize, u64)]) -> Result<(Streams, u64)> {
    let a = echo(M, Duration::ZERO);
    let b = echo(M, Duration::ZERO);
    let g = echo(2 * M, Duration::ZERO);
    let mut multi = MultiServer::new();
    let cfg = ServerConfig { max_wait: Duration::ZERO, queue_cap: 4096, ..lane_config() };
    multi.add_lane_qos(&a, cfg.clone(), LaneQos::new(1, FAR));
    multi.add_lane_qos(&b, cfg, LaneQos::new(1, FAR));
    let group = if coalesced { multi.auto_coalesce(&g)? } else { None };

    let mut streams: Streams = vec![Vec::new(); 2];
    let mut lane_of_id = std::collections::HashMap::new();
    let mut buf = Vec::new();
    for batch in arrivals.chunks(8) {
        for &(lane, model, id) in batch {
            lane_of_id.insert(id, lane);
            multi.offer(lane, seeded_request(id, model))?;
        }
        while multi.dispatch_next(&mut buf)?.is_some() {}
        collect_streams(&mut buf, &lane_of_id, &mut streams);
    }
    anyhow::ensure!(multi.pending() == 0, "oracle run left requests queued");
    Ok((streams, group.map_or(0, |g| multi.group_stats(g).rounds)))
}

fn routing_diffs(arrivals: usize, seed: u64) -> Result<(usize, u64)> {
    // seeded arrival sequence (timing ignored — this part is about
    // routing, not rates)
    let mut gen = LoadGen::new(
        TrafficShape::Poisson { rate: 1000.0 },
        &[(M, 1.0), (M, 1.0)],
        seed,
    )?;
    let seq: Vec<(usize, usize, u64)> = (0..arrivals)
        .map(|_| {
            let a = gen.next();
            (a.lane, a.model_idx, a.id)
        })
        .collect();
    let (want, _) = oracle_run(false, &seq)?;
    let (got, merged) = oracle_run(true, &seq)?;
    anyhow::ensure!(merged > 0, "oracle load must exercise merged rounds");
    let mut diffs = 0usize;
    for lane in 0..2 {
        if want[lane].len() != got[lane].len() {
            diffs += want[lane].len().abs_diff(got[lane].len());
            continue;
        }
        diffs += want[lane].iter().zip(&got[lane]).filter(|(w, g)| w != g).count();
    }
    Ok((diffs, merged))
}

// ---------------------------------------------------------------------------
// part 3: open-loop served throughput through the full ingress path
// ---------------------------------------------------------------------------

struct OpenRun {
    stats: IngressStats,
    sent: u64,
    responses: u64,
    rejects: u64,
    elapsed: f64,
    served_rps: f64,
}

fn open_loop(
    coalesced: bool,
    producers: usize,
    rate: f64,
    horizon: Duration,
    seed: u64,
) -> Result<OpenRun> {
    let a = echo(M, ROUND_COST);
    let b = echo(M, ROUND_COST);
    let g = echo(2 * M, ROUND_COST);
    let mut multi = MultiServer::new();
    multi.add_lane_qos(&a, lane_config(), LaneQos::new(1, FAR));
    multi.add_lane_qos(&b, lane_config(), LaneQos::new(1, FAR));
    if coalesced {
        multi.auto_coalesce(&g)?.expect("two same-family lanes must group");
    }
    let bridge = IngressBridge::new(1024);

    let gen = LoadGen::new(TrafficShape::Poisson { rate }, &[(M, 1.0), (M, 1.0)], seed)?;
    let shards = gen.shards(producers);

    type RunOutcome = (IngressStats, u64, u64, u64);
    let t0 = Instant::now();
    let (stats, sent, ok, rejected) = std::thread::scope(|s| -> Result<RunOutcome> {
        let bridge_ref = &bridge;
        let multi_ref = &mut multi;
        let dispatch = s.spawn(move || run_dispatch(multi_ref, bridge_ref));

        let mut conns = Vec::new();
        let mut receivers = Vec::new();
        let mut senders = Vec::new();
        for shard in shards {
            let (client, server_end) = ChanTransport::pair();
            let conn = serve_conn(bridge.clone(), Box::new(server_end))
                .expect("in-proc serve_conn cannot fail");
            conns.push(conn);
            let (mut tx, mut rx) = (Box::new(client) as Box<dyn Transport>)
                .split()
                .expect("in-proc split cannot fail");
            receivers.push(s.spawn(move || {
                let (mut ok, mut rejected) = (0u64, 0u64);
                loop {
                    match rx.recv() {
                        Ok(Some(Frame::Response { .. })) => ok += 1,
                        Ok(Some(Frame::Reject { .. })) => rejected += 1,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return (ok, rejected),
                    }
                }
            }));
            senders.push(s.spawn(move || {
                let sent = shard.drive(horizon, |a| {
                    let _ = tx.send(&Frame::Request {
                        id: a.id,
                        lane: a.lane as u32,
                        model_idx: a.model_idx as u32,
                        shape: INPUT_SHAPE.to_vec(),
                        data: vec![0.0; 4],
                    });
                });
                let _ = tx.send(&Frame::Eos);
                sent
            }));
        }

        let mut sent = 0u64;
        for t in senders {
            sent += t.join().unwrap();
        }
        bridge.close();
        let stats_res = dispatch.join().unwrap();
        // unwind connections BEFORE surfacing a dispatch error, or the
        // blocked receiver threads would hang the scope join
        for c in conns {
            c.shutdown();
        }
        let (mut ok, mut rejected) = (0u64, 0u64);
        for r in receivers {
            let (o, j) = r.join().unwrap();
            ok += o;
            rejected += j;
        }
        Ok((stats_res?, sent, ok, rejected))
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    Ok(OpenRun {
        sent,
        responses: ok,
        rejects: rejected,
        served_rps: ok as f64 / elapsed,
        elapsed,
        stats,
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "# coalesce: cross-fleet merged rounds vs lane-by-lane dispatch{}\n",
        if smoke { " (SMOKE)" } else { "" }
    );

    // --- part 1: saturated drive ----------------------------------------
    let sat_rounds = if smoke { 60 } else { 400 };
    let (solo_rps, solo_served, _) = saturated(false, sat_rounds)?;
    let (co_rps, co_served, merged) = saturated(true, sat_rounds)?;
    let sat_ratio = co_rps / solo_rps;
    println!(
        "saturated: solo {solo_rps:.0} req/s vs coalesced {co_rps:.0} req/s \
         ({sat_ratio:.2}x, {merged} merged rounds, {co_served}+{solo_served} served)"
    );

    // --- part 2: routing oracle ------------------------------------------
    let oracle_arrivals = if smoke { 400 } else { 4000 };
    let (diffs, oracle_merged) = routing_diffs(oracle_arrivals, 0xC0A1E5CE)?;
    println!(
        "oracle: {oracle_arrivals} seeded arrivals, {oracle_merged} merged rounds, \
         {diffs} routing diffs (must be 0)"
    );

    // --- part 3: open loop ------------------------------------------------
    let producers = 2;
    let (rate, horizon) = if smoke {
        (500.0, Duration::from_millis(150))
    } else {
        // ~3x one-round-per-lane capacity, ~1.5x merged capacity: the
        // solo baseline saturates, the merged run mostly keeps up
        (6000.0, Duration::from_millis(1500))
    };
    let solo = open_loop(false, producers, rate, horizon, 0x5EED)?;
    let co = open_loop(true, producers, rate, horizon, 0x5EED)?;
    let open_ratio = co.served_rps / solo.served_rps.max(1e-9);
    for (name, run) in [("solo", &solo), ("coalesced", &co)] {
        println!(
            "open-loop {name:<9}: sent {} -> {} responses + {} rejects in {:.2}s \
             ({:.0} served/s, {} merged of {} rounds)",
            run.sent,
            run.responses,
            run.rejects,
            run.elapsed,
            run.served_rps,
            run.stats.coalesced_rounds,
            run.stats.rounds,
        );
    }
    println!("open-loop served-throughput ratio: {open_ratio:.2}x\n");

    // --- BENCH_coalesce.json ----------------------------------------------
    let mut sat = BTreeMap::new();
    sat.insert("rounds".to_string(), num(sat_rounds as f64));
    sat.insert("solo_rps".to_string(), num(solo_rps));
    sat.insert("coalesced_rps".to_string(), num(co_rps));
    sat.insert("ratio".to_string(), num(sat_ratio));
    sat.insert("merged_rounds".to_string(), num(merged as f64));

    let mut oracle = BTreeMap::new();
    oracle.insert("arrivals".to_string(), num(oracle_arrivals as f64));
    oracle.insert("merged_rounds".to_string(), num(oracle_merged as f64));
    oracle.insert("routing_diffs".to_string(), num(diffs as f64));

    let mut open = BTreeMap::new();
    open.insert("producers".to_string(), num(producers as f64));
    open.insert("offered_rate_rps".to_string(), num(rate));
    open.insert("horizon_s".to_string(), num(horizon.as_secs_f64()));
    for (name, run) in [("solo", &solo), ("coalesced", &co)] {
        let mut r = BTreeMap::new();
        r.insert("sent".to_string(), num(run.sent as f64));
        r.insert("responses".to_string(), num(run.responses as f64));
        r.insert("rejects".to_string(), num(run.rejects as f64));
        r.insert("served_rps".to_string(), num(run.served_rps));
        r.insert("rounds".to_string(), num(run.stats.rounds as f64));
        r.insert("coalesced_rounds".to_string(), num(run.stats.coalesced_rounds as f64));
        open.insert(name.to_string(), Json::Obj(r));
    }
    open.insert("ratio".to_string(), num(open_ratio));

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("coalesce".to_string()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("round_cost_s".to_string(), num(ROUND_COST.as_secs_f64()));
    root.insert("models_per_lane".to_string(), num(M as f64));
    root.insert("saturated".to_string(), Json::Obj(sat));
    root.insert("oracle".to_string(), Json::Obj(oracle));
    root.insert("open_loop".to_string(), Json::Obj(open));

    let path = "BENCH_coalesce.json";
    std::fs::write(path, Json::Obj(root).dump())?;
    println!("report written to {path}");

    // correctness gates run in every mode (written AFTER the report so a
    // failing run still leaves its numbers behind)
    assert_eq!(diffs, 0, "coalesced routing diverged from the uncoalesced oracle");
    assert!(merged > 0, "saturated coalesced run dispatched no merged rounds");
    assert!(
        sat_ratio >= 1.3,
        "coalescing must serve >= 1.3x under saturation (one merged launch \
         for two lanes), got {sat_ratio:.2}x"
    );
    assert_eq!(
        solo.responses + solo.rejects,
        solo.sent,
        "every open-loop arrival needs exactly one outcome frame"
    );
    assert_eq!(
        co.responses + co.rejects,
        co.sent,
        "every open-loop arrival needs exactly one outcome frame"
    );
    // timing gates only in full runs (CI smoke must not flake on noise)
    if !smoke {
        assert!(
            co.stats.coalesced_rounds > 0,
            "open-loop coalesced run never merged a round"
        );
        assert!(
            open_ratio >= 1.3,
            "2 same-family lanes under open-loop load must serve >= 1.3x \
             coalesced vs uncoalesced, got {open_ratio:.2}x"
        );
    }
    Ok(())
}
