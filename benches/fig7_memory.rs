//! Bench: paper Figure 7 — peak memory (workspace + framework base) per
//! strategy on the V100 profile, plus the measured-bytes table from the
//! mini-model manifest. Reproduces the Concurrent OOM at 16 models.

use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let opts = FigOpts::default();
    println!("{}", figures::fig7(&opts)?);
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("{}", figures::fig7_measured(&rt, &opts)?);
    Ok(())
}
