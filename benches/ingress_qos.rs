//! Bench: open-loop ingress + SLO-aware QoS scheduling.
//!
//! Four parts, all offline (mock `RoundExecutor` lanes, no artifacts):
//!
//! 1. **WDRR ratio** — two permanently backlogged lanes with weights
//!    {3, 1}: the `QosScheduler` must dispatch rounds in a ~3:1 ratio.
//!    Deterministic (no timing), so the gate runs in every mode
//!    including `--smoke` on CI.
//! 1b. **Never-idle** — a deadline-free `run_dispatch` run where lane
//!    readiness can only change through the dispatch thread itself:
//!    `idle_naps_avoided` must be exactly 0, race-free in every mode
//!    (see `never_idle_run` for why the timed run can't gate this).
//! 2. **Open-loop serving** — 4 producer threads drive sharded Poisson
//!    arrivals (75% to the weighted lane) through in-proc transports,
//!    `serve_conn` readers, the bounded `IngressBridge`, and one
//!    `run_dispatch` thread owning the `MultiServer`. Gates: every
//!    arrival gets exactly one outcome frame (response or typed
//!    reject) and, in full runs only, the weighted lane's p99 stays
//!    under its 25ms SLO.
//! 3. **Closed-loop baseline** — the same lanes driven by the old
//!    offer-then-drain loop, for the rps comparison in the report.
//!
//! Results go to `BENCH_ingress_qos.json`. `--smoke` runs an
//! abbreviated open-loop pass with the timing gates off so CI exercises
//! the full frame->bridge->QoS->response path on every push.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::{Request, StrategyKind};
use netfuse::ingress::{
    run_dispatch, serve_conn, ChanTransport, Envelope, Frame, FrameQueue, IngressBridge,
    IngressStats, LaneQos, LoadGen, TrafficShape, Transport, TransportRx, TransportTx,
};
use netfuse::tensor::Tensor;
use netfuse::util::json::Json;

/// models per lane
const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
/// modeled device time per round
const ROUND_COST: Duration = Duration::from_micros(100);
/// the weighted (interactive) lane's latency target
const TIGHT_SLO: Duration = Duration::from_millis(25);
const LOOSE_SLO: Duration = Duration::from_millis(250);

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn echo(name: &str, round_cost: Duration) -> EchoExecutor {
    EchoExecutor::new(name, M, &[4], round_cost)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::Sequential,
        queue_cap: 512,
        max_wait: Duration::from_millis(3),
    }
}

fn payload() -> Tensor {
    Tensor::zeros(&INPUT_SHAPE)
}

// ---------------------------------------------------------------------------
// part 1: WDRR 3:1 ratio (deterministic, gated in every mode)
// ---------------------------------------------------------------------------

fn wdrr_ratio(rounds: usize) -> Result<f64> {
    let heavy = echo("heavy", Duration::ZERO);
    let light = echo("light", Duration::ZERO);
    let mut multi = MultiServer::new();
    let cfg = ServerConfig { max_wait: Duration::ZERO, ..lane_config() };
    multi.add_lane_qos(&heavy, cfg.clone(), LaneQos::new(3, Duration::from_secs(3600)));
    multi.add_lane_qos(&light, cfg, LaneQos::new(1, Duration::from_secs(3600)));

    let mut id = 0u64;
    let mut buf = Vec::new();
    let mut counts = [0usize; 2];
    for _ in 0..rounds {
        // keep both lanes backlogged so only the scheduler decides
        for lane in 0..2 {
            while multi.lane(lane).pending() < 4 {
                multi.offer(lane, Request::new(id, 0, payload()))?;
                id += 1;
            }
        }
        let d = multi
            .dispatch_next(&mut buf)?
            .expect("backlogged lanes are always dispatchable");
        buf.clear();
        counts[d.lane] += 1;
    }
    Ok(counts[0] as f64 / counts[1].max(1) as f64)
}

/// Deterministic never-idle gate. With `max_wait == 0` and a far-away
/// SLO, lane readiness is exactly `pending > 0`, which only the
/// dispatch thread's own admissions and dispatches can change — no
/// deadline can expire between `dispatch_next` saying "nothing due"
/// and the pre-nap recheck. So `idle_naps_avoided != 0` here is a real
/// scheduling bug, never a timing race, and the gate holds in every
/// mode. (In the timed QoS run the same counter can legitimately tick
/// when a 3ms/SLO deadline lands in that microsecond window, so there
/// it is reported, not gated.)
fn never_idle_run(envelopes: usize) -> Result<IngressStats> {
    let only = echo("only", Duration::ZERO);
    let mut multi = MultiServer::new();
    // queue_cap >= envelopes: the loop drains ALL bridge arrivals before
    // dispatching, so a scheduler stall must not turn the backlog into
    // Busy rejects (the gate asserts every envelope gets a response)
    multi.add_lane_qos(
        &only,
        ServerConfig { max_wait: Duration::ZERO, queue_cap: envelopes.max(1), ..lane_config() },
        LaneQos::new(1, Duration::from_secs(3600)),
    );
    let bridge = IngressBridge::new(envelopes.max(1));
    let reply = FrameQueue::new();
    let stats = std::thread::scope(|s| {
        let bridge_ref = &bridge;
        let reply_ref = &reply;
        let producer = s.spawn(move || {
            for i in 0..envelopes {
                let env = Envelope {
                    lane: 0,
                    client_id: i as u64,
                    req: Request::new(i as u64, i % M, payload()),
                    reply: reply_ref.clone(),
                };
                assert!(bridge_ref.submit(env).is_ok(), "bridge sized for every envelope");
                if i % 16 == 0 {
                    // gaps force genuine idle naps between bursts
                    std::thread::sleep(Duration::from_micros(300));
                }
            }
            bridge_ref.close();
        });
        let stats = run_dispatch(&mut multi, &bridge);
        producer.join().unwrap();
        stats
    })?;
    anyhow::ensure!(
        reply.len() as u64 == envelopes as u64 && stats.responses == envelopes as u64,
        "never-idle run must serve every envelope ({} of {envelopes})",
        stats.responses
    );
    Ok(stats)
}

// ---------------------------------------------------------------------------
// part 2: open-loop ingress through the full frame/bridge/QoS path
// ---------------------------------------------------------------------------

struct LaneReport {
    served: u64,
    p50: f64,
    p95: f64,
    p99: f64,
    slo_violations: u64,
    throughput: f64,
}

struct OpenLoopRun {
    stats: IngressStats,
    sent: u64,
    client_responses: u64,
    client_rejects: u64,
    elapsed: f64,
    lanes: Vec<LaneReport>,
}

fn open_loop(producers: usize, rate: f64, horizon: Duration, seed: u64) -> Result<OpenLoopRun> {
    let tight = echo("tight", ROUND_COST);
    let loose = echo("loose", ROUND_COST);
    let mut multi = MultiServer::new();
    multi.add_lane_qos(&tight, lane_config(), LaneQos::new(3, TIGHT_SLO));
    multi.add_lane_qos(&loose, lane_config(), LaneQos::new(1, LOOSE_SLO));
    let bridge = IngressBridge::new(1024);

    // 75% of traffic to the weighted lane, uniform across its models
    let gen = LoadGen::new(TrafficShape::Poisson { rate }, &[(M, 3.0), (M, 1.0)], seed)?;
    let shards = gen.shards(producers);

    type RunOutcome = (IngressStats, u64, u64, u64);
    let t0 = Instant::now();
    let (stats, sent, ok, rejected) = std::thread::scope(|s| -> Result<RunOutcome> {
        let bridge_ref = &bridge;
        let multi_ref = &mut multi;
        let dispatch = s.spawn(move || run_dispatch(multi_ref, bridge_ref));

        let mut conns = Vec::new();
        let mut receivers = Vec::new();
        let mut senders = Vec::new();
        for shard in shards {
            let (client, server_end) = ChanTransport::pair();
            // expect, not `?`: an early return here would leave the
            // dispatch thread parked and deadlock the scope join
            let conn = serve_conn(bridge.clone(), Box::new(server_end))
                .expect("in-proc serve_conn cannot fail");
            conns.push(conn);
            let (mut tx, mut rx) = (Box::new(client) as Box<dyn Transport>)
                .split()
                .expect("in-proc split cannot fail");
            receivers.push(s.spawn(move || {
                let (mut ok, mut rejected) = (0u64, 0u64);
                loop {
                    match rx.recv() {
                        Ok(Some(Frame::Response { .. })) => ok += 1,
                        Ok(Some(Frame::Reject { .. })) => rejected += 1,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return (ok, rejected),
                    }
                }
            }));
            senders.push(s.spawn(move || {
                let sent = shard.drive(horizon, |a| {
                    let _ = tx.send(&Frame::Request {
                        id: a.id,
                        lane: a.lane as u32,
                        model_idx: a.model_idx as u32,
                        shape: INPUT_SHAPE.to_vec(),
                        data: vec![0.0; 4],
                    });
                });
                let _ = tx.send(&Frame::Eos);
                sent
            }));
        }

        let mut sent = 0u64;
        for t in senders {
            sent += t.join().unwrap();
        }
        bridge.close();
        let stats_res = dispatch.join().unwrap();
        // unwind the connections BEFORE surfacing a dispatch error, or
        // the blocked receiver threads would hang the scope join
        for c in conns {
            c.shutdown();
        }
        let (mut ok, mut rejected) = (0u64, 0u64);
        for r in receivers {
            let (o, j) = r.join().unwrap();
            ok += o;
            rejected += j;
        }
        Ok((stats_res?, sent, ok, rejected))
    })?;
    let elapsed = t0.elapsed().as_secs_f64();

    let lanes = (0..multi.lanes())
        .map(|i| {
            let met = &multi.lane(i).metrics;
            LaneReport {
                served: met.completed_requests,
                p50: met.request_latency.p50(),
                p95: met.request_latency.p95(),
                p99: met.request_latency.p99(),
                slo_violations: met.slo_violations,
                throughput: met.throughput(),
            }
        })
        .collect();
    Ok(OpenLoopRun {
        stats,
        sent,
        client_responses: ok,
        client_rejects: rejected,
        elapsed,
        lanes,
    })
}

// ---------------------------------------------------------------------------
// part 3: closed-loop baseline (the old driver shape)
// ---------------------------------------------------------------------------

fn closed_loop(rounds: usize) -> Result<f64> {
    let tight = echo("tight", ROUND_COST);
    let loose = echo("loose", ROUND_COST);
    let mut multi = MultiServer::new();
    multi.add_lane_qos(&tight, lane_config(), LaneQos::new(3, TIGHT_SLO));
    multi.add_lane_qos(&loose, lane_config(), LaneQos::new(1, LOOSE_SLO));
    let mut id = 0u64;
    let mut buf = Vec::new();
    let t0 = Instant::now();
    let mut served = 0u64;
    for _ in 0..rounds {
        for lane in 0..2 {
            for model in 0..M {
                multi.offer(lane, Request::new(id, model, payload()))?;
                id += 1;
            }
        }
        while let Some(d) = multi.dispatch_next(&mut buf)? {
            served += d.responses as u64;
            buf.clear();
        }
    }
    served += multi.drain(&mut buf)? as u64;
    Ok(served as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "# ingress_qos: open-loop ingress + WDRR/SLO scheduling{}\n",
        if smoke { " (SMOKE)" } else { "" }
    );

    // --- part 1: WDRR ratio ---------------------------------------------
    let ratio_rounds = if smoke { 200 } else { 1000 };
    let ratio = wdrr_ratio(ratio_rounds)?;
    println!("wdrr: weights 3:1 dispatched {ratio:.2}:1 over {ratio_rounds} rounds");

    // --- part 1b: deterministic never-idle gate --------------------------
    let ni_envelopes = if smoke { 200 } else { 2000 };
    let ni = never_idle_run(ni_envelopes)?;
    println!(
        "never-idle: {ni_envelopes} bursty envelopes, {} rounds, \
         {} naps-while-ready (must be 0)",
        ni.rounds, ni.idle_naps_avoided
    );

    // --- part 2: open loop ----------------------------------------------
    let producers = 4;
    let (rate, horizon) = if smoke {
        (400.0, Duration::from_millis(150))
    } else {
        (2000.0, Duration::from_secs(2))
    };
    let run = open_loop(producers, rate, horizon, 0x1A6E55)?;
    let outcomes = run.client_responses + run.client_rejects;
    println!(
        "open-loop: {} producers at {rate:.0} req/s for {horizon:?}: sent {} -> \
         {} responses + {} rejects in {:.2}s",
        producers, run.sent, run.client_responses, run.client_rejects, run.elapsed
    );
    for (i, lane) in run.lanes.iter().enumerate() {
        println!(
            "  lane {i}: served {:<6} p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms \
             slo_viol {} ({:.0} req/s)",
            lane.served,
            lane.p50 * 1e3,
            lane.p95 * 1e3,
            lane.p99 * 1e3,
            lane.slo_violations,
            lane.throughput,
        );
    }

    // --- part 3: closed-loop baseline -----------------------------------
    let closed_rounds = if smoke { 20 } else { 500 };
    let closed_rps = closed_loop(closed_rounds)?;
    println!("closed-loop baseline: {closed_rps:.0} req/s over {closed_rounds} rounds\n");

    // --- BENCH_ingress_qos.json -----------------------------------------
    let mut wdrr = BTreeMap::new();
    wdrr.insert("rounds".to_string(), num(ratio_rounds as f64));
    wdrr.insert("weights".to_string(), Json::Str("3:1".to_string()));
    wdrr.insert("dispatch_ratio".to_string(), num(ratio));

    let mut never_idle = BTreeMap::new();
    never_idle.insert("envelopes".to_string(), num(ni_envelopes as f64));
    never_idle.insert("rounds".to_string(), num(ni.rounds as f64));
    never_idle.insert("naps_while_ready".to_string(), num(ni.idle_naps_avoided as f64));

    let mut open = BTreeMap::new();
    open.insert("producers".to_string(), num(producers as f64));
    open.insert("offered_rate_rps".to_string(), num(rate));
    open.insert("horizon_s".to_string(), num(horizon.as_secs_f64()));
    open.insert("sent".to_string(), num(run.sent as f64));
    open.insert("responses".to_string(), num(run.client_responses as f64));
    open.insert("rejects".to_string(), num(run.client_rejects as f64));
    open.insert("rounds".to_string(), num(run.stats.rounds as f64));
    open.insert("admitted".to_string(), num(run.stats.admitted as f64));
    open.insert("lane_busy".to_string(), num(run.stats.lane_busy as f64));
    open.insert("idle_naps_avoided".to_string(), num(run.stats.idle_naps_avoided as f64));
    for (i, lane) in run.lanes.iter().enumerate() {
        let mut l = BTreeMap::new();
        l.insert("served".to_string(), num(lane.served as f64));
        l.insert("p50_s".to_string(), num(lane.p50));
        l.insert("p95_s".to_string(), num(lane.p95));
        l.insert("p99_s".to_string(), num(lane.p99));
        l.insert("slo_violations".to_string(), num(lane.slo_violations as f64));
        l.insert("throughput_rps".to_string(), num(lane.throughput));
        open.insert(format!("lane{i}"), Json::Obj(l));
    }

    let mut closed = BTreeMap::new();
    closed.insert("rounds".to_string(), num(closed_rounds as f64));
    closed.insert("req_per_sec".to_string(), num(closed_rps));

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("ingress_qos".to_string()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("round_cost_s".to_string(), num(ROUND_COST.as_secs_f64()));
    root.insert("tight_slo_s".to_string(), num(TIGHT_SLO.as_secs_f64()));
    root.insert("wdrr".to_string(), Json::Obj(wdrr));
    root.insert("never_idle".to_string(), Json::Obj(never_idle));
    root.insert("open_loop".to_string(), Json::Obj(open));
    root.insert("closed_loop".to_string(), Json::Obj(closed));

    let path = "BENCH_ingress_qos.json";
    std::fs::write(path, Json::Obj(root).dump())?;
    println!("report written to {path}");

    // correctness gates run in every mode (written AFTER the report so
    // a failing run still leaves its numbers behind)
    assert!(
        (2.5..=3.5).contains(&ratio),
        "WDRR weights 3:1 must dispatch ~3:1 rounds, got {ratio:.2}:1"
    );
    assert_eq!(
        outcomes, run.sent,
        "every open-loop arrival needs exactly one outcome frame \
         ({} responses + {} rejects != {} sent)",
        run.client_responses, run.client_rejects, run.sent
    );
    assert_eq!(
        ni.idle_naps_avoided, 0,
        "the dispatch thread was about to nap while a lane was round-ready \
         (deterministic run — this is a scheduling bug, not a timing race)"
    );
    // timing gates only in full runs (CI smoke must not flake on noise)
    if !smoke {
        let tight = &run.lanes[0];
        assert!(
            tight.p99 <= TIGHT_SLO.as_secs_f64(),
            "weighted lane p99 {:.1}ms must stay under its {:.0}ms SLO",
            tight.p99 * 1e3,
            TIGHT_SLO.as_secs_f64() * 1e3
        );
    }
    Ok(())
}
