//! Bench: paper Figure 6 — BERT inference time normalized to NETFUSE for
//! batch sizes 1..8. Reproduces the crossover where a saturated GPU
//! stops benefiting from merging (bs=8).

use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("NETFUSE_BENCH_FULL").is_ok();
    let mut opts = FigOpts::default();
    opts.models = vec!["bert".into()];
    if !full {
        opts.m_sweep = vec![8, 32];
        opts.samples = 5;
    }
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("{}", figures::fig6(Some(&rt), &opts)?);
    Ok(())
}
