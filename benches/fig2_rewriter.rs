//! Bench: paper Figure 2 + §2.2 — the TASO-like greedy rewriter does not
//! find the cross-model grouped-conv merge; Algorithm 1 encodes it
//! directly. Also prints the §2.2 search-space growth argument.

use netfuse::figures;

fn main() -> anyhow::Result<()> {
    println!("{}", figures::fig2()?);
    Ok(())
}
