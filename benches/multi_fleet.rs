//! Bench: cross-round overlap (double-buffered `ArenaRing`) and
//! multi-fleet serving on one shared `WorkerPool`.
//!
//! Part 1 — overlap. PR 1's NETFUSE path held ONE arena lock across
//! pack + stage + execute, so two rounds could never overlap even from
//! different threads. The `ArenaRing::pair` form reserves one slot per
//! round; the other slot stays free, so thread B packs + stages round N+1 while
//! round N is still executing. Device execution is modeled as a
//! fixed-latency blocking call that reads the staged host buffer at
//! execute time (the deferred-H2D contract of PJRT host buffers), which
//! is exactly the span the host is *not* allowed to repack — and the
//! span double-buffering reclaims. Gate: 2-thread round throughput with
//! the pair >= 1.5x the single-buffer lock-spanning baseline.
//!
//! Part 2 — multi-fleet. Serves two fleets through `MultiServer` twice:
//! once with a dedicated `WorkerPool` per fleet (the PR 1 cost model),
//! once with ONE shared pool. Gate: the shared pool spawns fewer
//! workers than the per-fleet pools combined while serving the same
//! traffic.
//!
//! Runs fully offline (no artifacts, no PJRT): the fleets are mock
//! `RoundExecutor`s. Results go to `BENCH_multi_fleet.json`.
//! `--smoke` runs one abbreviated iteration with no perf gates so CI
//! exercises the overlap path on every push.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use netfuse::coordinator::arena::{ArenaRing, Layout, RoundArena};
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::pool::WorkerPool;
use netfuse::coordinator::server::{Admit, ServerConfig};
use netfuse::coordinator::service::RoundExecutor;
use netfuse::coordinator::{Request, StrategyKind};
use netfuse::tensor::Tensor;
use netfuse::util::json::Json;
use netfuse::util::rng::Rng;

const M: usize = 16;
const REQUEST_SHAPE: [usize; 4] = [1, 3, 16, 16];
/// modeled device execution latency per merged round
const DEVICE_LATENCY: Duration = Duration::from_micros(500);

fn num(v: f64) -> Json {
    Json::Num(v)
}

// ---------------------------------------------------------------------------
// part 1: single-buffer lock-spanning rounds vs double-buffered ArenaRing
// ---------------------------------------------------------------------------

/// Stand-in for `Bound::stage`/`run_staged` against a device whose
/// executions proceed concurrently (PJRT executables are internally
/// synchronized; concurrent submissions overlap). `stage` borrows the
/// host megabatch — the deferred-H2D contract — and `run` reads it at
/// execute time, then blocks for the device latency.
struct FakeDevice {
    latency: Duration,
    checksum: AtomicU64,
}

struct FakeStaged<'a> {
    data: &'a [f32],
}

impl FakeDevice {
    fn new(latency: Duration) -> FakeDevice {
        FakeDevice { latency, checksum: AtomicU64::new(0) }
    }

    fn stage<'a>(&self, data: &'a [f32]) -> FakeStaged<'a> {
        FakeStaged { data }
    }

    fn run(&self, staged: &FakeStaged<'_>) {
        // deferred H2D: the host buffer is only consumed here, which is
        // why the packed half must stay reserved until run completes
        let sum: f32 = staged.data.iter().sum();
        self.checksum.fetch_add(sum.to_bits() as u64, Ordering::Relaxed);
        std::thread::sleep(self.latency);
    }
}

/// The staging buffers under test: PR 1's one lock-spanning arena, or
/// the double-buffered pair.
enum Buffers {
    Single(Mutex<RoundArena>),
    Pair(ArenaRing),
}

/// `threads` workers each driving `rounds` NETFUSE-shaped rounds.
/// Returns rounds/sec.
fn overlap_throughput(
    threads: usize,
    rounds: usize,
    double_buffered: bool,
    xs: &[Tensor],
) -> Result<f64> {
    let device = FakeDevice::new(DEVICE_LATENCY);
    let buffers = if double_buffered {
        Buffers::Pair(ArenaRing::pair(Layout::Channel, M, &REQUEST_SHAPE)?)
    } else {
        Buffers::Single(Mutex::new(RoundArena::new(Layout::Channel, M, &REQUEST_SHAPE)?))
    };
    // one round: pack + stage + execute on whichever arena is handed in
    let round = |arena: &mut RoundArena| {
        arena.pack_with(&|i| Some(&xs[i])).unwrap();
        let staged = device.stage(arena.merged_data());
        device.run(&staged);
    };

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                for _ in 0..rounds {
                    match &buffers {
                        // reserve ONE half for pack + stage + execute;
                        // the other half is free for the peer thread
                        Buffers::Pair(pair) => round(&mut pair.acquire()),
                        // PR 1: the one arena lock spans the round
                        Buffers::Single(single) => round(&mut single.lock().unwrap()),
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    Ok((threads * rounds) as f64 / elapsed)
}

// ---------------------------------------------------------------------------
// part 2: MultiServer over mock fleets — dedicated pools vs one shared pool
// ---------------------------------------------------------------------------

/// Mock fleet: echoes payloads, burns a little CPU per model on its
/// worker pool (Concurrent dispatch), like a single-model executable.
struct BenchFleet {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    pool: Arc<WorkerPool>,
}

impl RoundExecutor for BenchFleet {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        outs.clear();
        let procs = match strategy {
            StrategyKind::Concurrent => self.m,
            StrategyKind::Hybrid { procs } => procs.min(self.m),
            _ => 1,
        };
        self.pool.ensure_workers(procs);
        let results = self.pool.run_chunked(self.m, procs, |i| {
            Ok(get(i).map(|x| {
                // model "compute": a checksum sweep over the payload
                let mut acc = 0.0f32;
                for _ in 0..8 {
                    acc += x.data().iter().sum::<f32>();
                }
                std::hint::black_box(acc);
                x.clone()
            }))
        })?;
        outs.extend(results);
        Ok(())
    }
}

/// Serve `rounds` full rounds to two fleets through a MultiServer.
/// Returns (requests served, requests/sec, total workers spawned).
fn multi_fleet_throughput(
    fleet_a: &BenchFleet,
    fleet_b: &BenchFleet,
    rounds: usize,
    rng: &mut Rng,
) -> Result<(u64, f64, usize)> {
    let mut multi = MultiServer::new();
    let a = multi.add_lane(
        fleet_a,
        ServerConfig { strategy: StrategyKind::Concurrent, ..Default::default() },
    );
    let b = multi.add_lane(
        fleet_b,
        ServerConfig { strategy: StrategyKind::Hybrid { procs: 2 }, ..Default::default() },
    );
    let shape = [1usize, 4];
    let mut buf = Vec::new();
    let mut id = 0u64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        for (lane, m) in [(a, fleet_a.m), (b, fleet_b.m)] {
            for model in 0..m {
                let req = Request::new(id, model, Tensor::randn(&shape, rng));
                id += 1;
                anyhow::ensure!(
                    multi.offer(lane, req)? == Admit::Queued,
                    "bench queue overflow"
                );
            }
        }
        while multi.dispatch_next(&mut buf)?.is_some() {}
        buf.clear();
    }
    multi.drain(&mut buf)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let served = multi.lane(a).metrics.completed_requests
        + multi.lane(b).metrics.completed_requests;
    let workers = fleet_a.pool.workers()
        + if Arc::ptr_eq(&fleet_a.pool, &fleet_b.pool) { 0 } else { fleet_b.pool.workers() };
    Ok((served, served as f64 / elapsed.max(1e-9), workers))
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Rng::new(0xF1EE7);
    let xs: Vec<Tensor> = (0..M).map(|_| Tensor::randn(&REQUEST_SHAPE, &mut rng)).collect();

    println!(
        "# multi_fleet: cross-round overlap + shared-pool tenancy (m={M}{})\n",
        if smoke { ", SMOKE" } else { "" }
    );

    // --- part 1: overlap ------------------------------------------------
    let rounds = if smoke { 2 } else { 200 };
    // warm-up pass so thread spawn / allocator noise stays out of the
    // measured runs
    overlap_throughput(2, 2, true, &xs)?;
    overlap_throughput(2, 2, false, &xs)?;
    let single_rps = overlap_throughput(2, rounds, false, &xs)?;
    let double_rps = overlap_throughput(2, rounds, true, &xs)?;
    let speedup = double_rps / single_rps;
    println!(
        "overlap: single-buffer {single_rps:.0} rounds/s  double-buffer \
         {double_rps:.0} rounds/s  speedup {speedup:.2}x"
    );

    // --- part 2: multi-fleet serving ------------------------------------
    let serve_rounds = if smoke { 2 } else { 50 };
    // dedicated pools: the PR 1 cost model, one pool per fleet
    let ded_a = BenchFleet {
        name: "fleet-a".into(),
        m: 8,
        input_shape: vec![4],
        pool: WorkerPool::shared(1),
    };
    let ded_b = BenchFleet {
        name: "fleet-b".into(),
        m: 6,
        input_shape: vec![4],
        pool: WorkerPool::shared(1),
    };
    let (ded_served, ded_rps, ded_workers) =
        multi_fleet_throughput(&ded_a, &ded_b, serve_rounds, &mut rng)?;

    // shared pool: ONE thread set for both fleets
    let pool = WorkerPool::shared(1);
    let sh_a = BenchFleet {
        name: "fleet-a".into(),
        m: 8,
        input_shape: vec![4],
        pool: pool.clone(),
    };
    let sh_b = BenchFleet {
        name: "fleet-b".into(),
        m: 6,
        input_shape: vec![4],
        pool: pool.clone(),
    };
    let (sh_served, sh_rps, sh_workers) =
        multi_fleet_throughput(&sh_a, &sh_b, serve_rounds, &mut rng)?;

    println!(
        "multi-fleet: dedicated pools {ded_workers} workers ({ded_rps:.0} req/s)  \
         shared pool {sh_workers} workers ({sh_rps:.0} req/s)"
    );

    // --- BENCH_multi_fleet.json -----------------------------------------
    let mut overlap = BTreeMap::new();
    overlap.insert("threads".to_string(), num(2.0));
    overlap.insert("rounds_per_thread".to_string(), num(rounds as f64));
    overlap.insert(
        "device_latency_s".to_string(),
        num(DEVICE_LATENCY.as_secs_f64()),
    );
    overlap.insert("single_buffer_rounds_per_sec".to_string(), num(single_rps));
    overlap.insert("double_buffer_rounds_per_sec".to_string(), num(double_rps));
    overlap.insert("speedup".to_string(), num(speedup));

    let mut mf = BTreeMap::new();
    mf.insert("fleets".to_string(), num(2.0));
    mf.insert("rounds".to_string(), num(serve_rounds as f64));
    mf.insert("dedicated_pool_workers".to_string(), num(ded_workers as f64));
    mf.insert("shared_pool_workers".to_string(), num(sh_workers as f64));
    mf.insert("dedicated_req_per_sec".to_string(), num(ded_rps));
    mf.insert("shared_req_per_sec".to_string(), num(sh_rps));
    mf.insert("requests_served".to_string(), num(sh_served as f64));

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("multi_fleet".to_string()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("overlap".to_string(), Json::Obj(overlap));
    root.insert("multi_fleet".to_string(), Json::Obj(mf));

    let path = "BENCH_multi_fleet.json";
    std::fs::write(path, Json::Obj(root).dump())?;
    println!("report written to {path}");

    // correctness gates run in every mode; perf gates only in full runs
    // (written AFTER the report so a noisy run leaves its numbers)
    assert_eq!(ded_served, sh_served, "both configurations must serve all requests");
    assert!(
        sh_workers < ded_workers,
        "shared pool must spawn fewer workers ({sh_workers}) than per-fleet pools ({ded_workers})"
    );
    if !smoke {
        assert!(
            speedup >= 1.5,
            "double-buffered rounds must be >= 1.5x the lock-spanning baseline \
             (got {speedup:.2}x)"
        );
    }
    Ok(())
}
