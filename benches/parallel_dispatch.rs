//! Bench: N-thread dispatch over an `ArenaRing` — `ParallelDispatcher`
//! vs the single-thread dispatch loop.
//!
//! Two parts, all offline. The lanes are `RingEcho` executors: every
//! round reserves a slot of a SHARED `ArenaRing`, packs its payloads
//! into the slot's megabatch (`RoundArena::pack_with`), holds the
//! reservation across the modeled device time (the deferred-H2D
//! contract), and echoes outputs back *out of the staged buffer* — so
//! ring reservation and staging integrity are in-path for every gate,
//! across all dispatch threads at once.
//!
//! 1. **Served throughput** — 4 coalesce groups (8 lanes) kept fully
//!    loaded through the real ingress path (bridge -> router -> per-
//!    group dispatch threads). The single-thread baseline serializes
//!    the four groups' rounds on one dispatch loop; the parallel run
//!    overlaps them, one thread per group. Gate (every mode, sleep-
//!    dominated so CI-safe): served throughput >= 1.5x the baseline
//!    (it is ~4x by construction at 4 groups).
//! 2. **Routing oracle** — a seeded arrival sequence over a mixed
//!    topology (two coalesce groups + two standalone lanes) served by
//!    `run_dispatch` (sequential) and `run_dispatch_parallel`, with
//!    zero-cost executors; the per-(lane, model) FIFO response streams
//!    are diffed byte-for-byte. Gate (every mode): **zero diffs** —
//!    partitioned dispatch may never misroute, reorder a model queue,
//!    or corrupt a payload, and every arrival gets exactly one outcome
//!    frame.
//!
//! Results go to `BENCH_parallel_dispatch.json`.

use std::collections::{BTreeMap, HashMap};
use std::time::{Duration, Instant};

use anyhow::Result;

use std::sync::Arc;

use netfuse::coordinator::arena::{ArenaRing, Layout};
use netfuse::coordinator::metrics::MetricsHub;
use netfuse::coordinator::multi::{GroupSpec, LaneSpec, MultiServer, ParallelDispatcher};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::service::RoundExecutor;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch, run_dispatch_parallel, Envelope, Frame, FrameQueue, IngressBridge,
    IngressStats, LaneQos,
};
use netfuse::tensor::Tensor;
use netfuse::util::bench::report::BenchReport;
use netfuse::util::json::Json;
use netfuse::util::rng::Rng;

/// The shared test scaffolding (seeded request builder) — the oracle
/// diff must use the same payload-seeding scheme as the test suites.
#[path = "../rust/tests/common/mod.rs"]
mod common;

/// models per lane (group executors run 2 * M slots)
const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
/// modeled device time per round — solo or merged, ONE launch. The
/// throughput part is sleep-dominated, so the >= 1.5x gate measures
/// dispatch-thread overlap, not host jitter.
const ROUND_COST: Duration = Duration::from_millis(1);
const FAR: Duration = Duration::from_secs(3600);

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 8192,
        max_wait: Duration::ZERO,
    }
}

// ---------------------------------------------------------------------------
// topology builders: `groups` coalesce groups of 2 lanes + `solos`
// standalone lanes, all same shape (groups use per-group families)
// ---------------------------------------------------------------------------

/// Echo executor that stages every round through a shared [`ArenaRing`]:
/// reserve a slot, pack the occupied payloads into its megabatch, hold
/// the reservation across the modeled device time (PJRT's deferred-H2D
/// contract), then read each occupied window back OUT of the staged
/// buffer as the round's outputs. Concurrent rounds from different
/// dispatch threads therefore contend for — and must never corrupt —
/// the same ring the way real `Fleet`s do.
struct RingEcho {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    ring: Arc<ArenaRing>,
    round_cost: Duration,
}

impl RingEcho {
    fn new(name: &str, ring: Arc<ArenaRing>, round_cost: Duration) -> RingEcho {
        RingEcho {
            name: name.to_string(),
            m: ring.m(),
            input_shape: ring.request_shape()[1..].to_vec(),
            ring,
            round_cost,
        }
    }
}

impl RoundExecutor for RingEcho {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        // pack + "execute" + unpack, all under ONE ring reservation
        let mut slot = self.ring.acquire();
        slot.pack_with(get)?;
        if !self.round_cost.is_zero() {
            std::thread::sleep(self.round_cost);
        }
        let inner: usize = self.input_shape.iter().product();
        outs.clear();
        for i in 0..self.m {
            outs.push(match get(i) {
                Some(_) => {
                    let window = &slot.merged_data()[i * inner..(i + 1) * inner];
                    let mut shape = vec![1usize];
                    shape.extend_from_slice(&self.input_shape);
                    Some(Tensor::new(shape, window.to_vec())?)
                }
                None => None,
            });
        }
        Ok(())
    }
}

struct Execs {
    lanes: Vec<RingEcho>,
    group_execs: Vec<RingEcho>,
    groups: usize,
}

impl Execs {
    fn new(groups: usize, solos: usize, cost: Duration) -> Execs {
        // ONE ring per megabatch shape, shared across every executor of
        // that shape — and therefore across every dispatch thread. The
        // depth matches the dispatch-thread count so full parallelism
        // never blocks on a staging buffer.
        let depth = (groups + solos).max(2);
        let lane_ring = Arc::new(
            ArenaRing::new(Layout::Batch, M, &INPUT_SHAPE, depth).expect("lane ring"),
        );
        let group_ring = Arc::new(
            ArenaRing::new(Layout::Batch, 2 * M, &INPUT_SHAPE, depth).expect("group ring"),
        );
        let mut lanes = Vec::new();
        let mut group_execs = Vec::new();
        for g in 0..groups {
            let family = format!("fam{g}");
            lanes.push(RingEcho::new(&family, lane_ring.clone(), cost));
            lanes.push(RingEcho::new(&family, lane_ring.clone(), cost));
            group_execs.push(RingEcho::new(&family, group_ring.clone(), cost));
        }
        for s in 0..solos {
            lanes.push(RingEcho::new(&format!("solo{s}"), lane_ring.clone(), cost));
        }
        Execs { lanes, group_execs, groups }
    }

    fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    fn dispatcher(&self) -> Result<ParallelDispatcher<'_, RingEcho>> {
        let lanes = self
            .lanes
            .iter()
            .map(|x| LaneSpec::new(x, lane_config(), LaneQos::new(1, FAR)))
            .collect();
        let groups = (0..self.groups)
            .map(|g| GroupSpec::new(&self.group_execs[g], &[2 * g, 2 * g + 1]))
            .collect();
        ParallelDispatcher::new(lanes, groups)
    }

    fn single(&self) -> Result<MultiServer<'_, RingEcho>> {
        let mut multi = MultiServer::new();
        for x in &self.lanes {
            multi.add_lane_qos(x, lane_config(), LaneQos::new(1, FAR));
        }
        for g in 0..self.groups {
            multi.add_coalesce_group(&self.group_execs[g], &[2 * g, 2 * g + 1])?;
        }
        Ok(multi)
    }
}

/// Pre-load `arrivals` into a bridge (sized to hold them all) with one
/// reply queue per lane, close it, and return both.
fn load_bridge(
    arrivals: &[(usize, usize, u64)],
    lanes: usize,
) -> (IngressBridge, Vec<FrameQueue>) {
    let bridge = IngressBridge::new(arrivals.len().max(1));
    let replies: Vec<FrameQueue> = (0..lanes).map(|_| FrameQueue::new()).collect();
    for &(lane, model, id) in arrivals {
        let env = Envelope {
            lane,
            client_id: id,
            req: common::seeded_request(id, model, &INPUT_SHAPE[1..]),
            reply: replies[lane].clone(),
        };
        assert!(bridge.submit(env).is_ok(), "bridge is sized for the whole workload");
    }
    bridge.close();
    (bridge, replies)
}

fn count_responses(replies: &[FrameQueue]) -> (u64, u64) {
    let (mut responses, mut rejects) = (0u64, 0u64);
    for q in replies {
        q.close();
        while let Some(f) = q.try_pop() {
            match f {
                Frame::Response { .. } => responses += 1,
                Frame::Reject { .. } => rejects += 1,
                _ => {}
            }
        }
    }
    (responses, rejects)
}

// ---------------------------------------------------------------------------
// part 1: served throughput, 4 groups, parallel vs single-thread
// ---------------------------------------------------------------------------

struct ThroughputRun {
    served: u64,
    elapsed: f64,
    rps: f64,
    stats: IngressStats,
}

fn throughput(execs: &Execs, rounds: usize, parallel: bool) -> Result<ThroughputRun> {
    // `rounds` full rounds of work per lane, pre-loaded so both runs
    // measure pure dispatch (producers out of the picture)
    let mut arrivals = Vec::new();
    let mut id = 0u64;
    for _ in 0..rounds {
        for lane in 0..execs.lane_count() {
            for model in 0..M {
                arrivals.push((lane, model, id));
                id += 1;
            }
        }
    }
    let (bridge, replies) = load_bridge(&arrivals, execs.lane_count());

    let t0 = Instant::now();
    let stats = if parallel {
        let mut d = execs.dispatcher()?;
        run_dispatch_parallel(&mut d, &bridge, arrivals.len())?
    } else {
        let mut multi = execs.single()?;
        run_dispatch(&mut multi, &bridge)?
    };
    let elapsed = t0.elapsed().as_secs_f64();

    let (responses, rejects) = count_responses(&replies);
    anyhow::ensure!(rejects == 0, "saturated drive must not shed load ({rejects} rejects)");
    anyhow::ensure!(
        responses == arrivals.len() as u64,
        "every request must be served ({responses} of {})",
        arrivals.len()
    );
    Ok(ThroughputRun {
        served: responses,
        elapsed,
        rps: responses as f64 / elapsed,
        stats,
    })
}

// ---------------------------------------------------------------------------
// part 2: routing oracle — parallel vs sequential, byte-exact
// ---------------------------------------------------------------------------

type ModelStreams = HashMap<(usize, u32), Vec<(u64, Vec<f32>)>>;

fn oracle_run(
    execs: &Execs,
    arrivals: &[(usize, usize, u64)],
    parallel: bool,
) -> Result<(ModelStreams, IngressStats)> {
    let (bridge, replies) = load_bridge(arrivals, execs.lane_count());
    let stats = if parallel {
        let mut d = execs.dispatcher()?;
        // sharded lane metrics ride along with the oracle run: the
        // merged hub view must account for every served request, so
        // "byte-identical to the sequential oracle" is checked WITH the
        // sharded recording enabled, not around it
        let hub = MetricsHub::new(d.parts());
        d.attach_metrics_hub(&hub);
        let stats = run_dispatch_parallel(&mut d, &bridge, arrivals.len().max(1))?;
        anyhow::ensure!(
            hub.read().completed_requests == stats.responses,
            "sharded metrics saw {} completions but ingress routed {} responses",
            hub.read().completed_requests,
            stats.responses
        );
        stats
    } else {
        let mut multi = execs.single()?;
        run_dispatch(&mut multi, &bridge)?
    };
    let mut streams: ModelStreams = HashMap::new();
    for (lane, q) in replies.iter().enumerate() {
        q.close();
        while let Some(f) = q.try_pop() {
            if let Frame::Response { id, model_idx, data, .. } = f {
                streams.entry((lane, model_idx)).or_default().push((id, data));
            }
        }
    }
    Ok((streams, stats))
}

fn routing_diffs(execs: &Execs, arrivals: usize, seed: u64) -> Result<(usize, u64, u64)> {
    let mut rng = Rng::new(seed);
    let seq: Vec<(usize, usize, u64)> = (0..arrivals)
        .map(|id| {
            (rng.usize_below(execs.lane_count()), rng.usize_below(M), id as u64)
        })
        .collect();
    let (want, seq_stats) = oracle_run(execs, &seq, false)?;
    let (got, par_stats) = oracle_run(execs, &seq, true)?;
    anyhow::ensure!(
        seq_stats.responses == arrivals as u64 && par_stats.responses == arrivals as u64,
        "oracle runs must answer every arrival"
    );
    anyhow::ensure!(par_stats.coalesced_rounds > 0, "oracle load must merge rounds");

    let mut diffs = 0usize;
    let mut keys: Vec<_> = want.keys().chain(got.keys()).collect();
    keys.sort();
    keys.dedup();
    for key in keys {
        match (want.get(key), got.get(key)) {
            (Some(w), Some(g)) if w == g => {}
            (Some(w), Some(g)) => {
                diffs += w.len().max(g.len());
            }
            (Some(w), None) | (None, Some(w)) => diffs += w.len(),
            (None, None) => unreachable!(),
        }
    }
    Ok((diffs, seq_stats.coalesced_rounds, par_stats.coalesced_rounds))
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "# parallel_dispatch: N dispatch threads over lane groups vs one{}\n",
        if smoke { " (SMOKE)" } else { "" }
    );

    // --- part 1: served throughput at 4 groups -------------------------
    let groups = 4usize;
    let rounds = if smoke { 50 } else { 250 };
    let sat = Execs::new(groups, 0, ROUND_COST);
    let single = throughput(&sat, rounds, false)?;
    let parallel = throughput(&sat, rounds, true)?;
    let ratio = parallel.rps / single.rps.max(1e-9);
    for (name, run) in [("single-thread", &single), ("parallel x4 ", &parallel)] {
        println!(
            "{name}: {} served in {:.3}s ({:.0} req/s, {} rounds, {} merged)",
            run.served, run.elapsed, run.rps, run.stats.rounds, run.stats.coalesced_rounds
        );
    }
    println!("served-throughput ratio: {ratio:.2}x\n");

    // --- part 2: routing oracle ----------------------------------------
    let mixed = Execs::new(2, 2, Duration::ZERO);
    let oracle_arrivals = if smoke { 600 } else { 6000 };
    let (diffs, seq_merged, par_merged) =
        routing_diffs(&mixed, oracle_arrivals, 0x9A8A11E1)?;
    println!(
        "oracle: {oracle_arrivals} seeded arrivals over {} lanes ({} groups + 2 solo), \
         {seq_merged}/{par_merged} merged rounds (seq/par), {diffs} routing diffs (must be 0)",
        mixed.lane_count(),
        2,
    );

    // --- BENCH_parallel_dispatch.json -----------------------------------
    let mut sat_obj = BTreeMap::new();
    sat_obj.insert("groups".to_string(), num(groups as f64));
    sat_obj.insert("rounds_per_lane".to_string(), num(rounds as f64));
    sat_obj.insert("round_cost_s".to_string(), num(ROUND_COST.as_secs_f64()));
    for (name, run) in [("single", &single), ("parallel", &parallel)] {
        let mut r = BTreeMap::new();
        r.insert("served".to_string(), num(run.served as f64));
        r.insert("elapsed_s".to_string(), num(run.elapsed));
        r.insert("served_rps".to_string(), num(run.rps));
        r.insert("rounds".to_string(), num(run.stats.rounds as f64));
        r.insert(
            "coalesced_rounds".to_string(),
            num(run.stats.coalesced_rounds as f64),
        );
        sat_obj.insert(name.to_string(), Json::Obj(r));
    }
    sat_obj.insert("ratio".to_string(), num(ratio));

    let mut oracle_obj = BTreeMap::new();
    oracle_obj.insert("arrivals".to_string(), num(oracle_arrivals as f64));
    oracle_obj.insert("merged_rounds_seq".to_string(), num(seq_merged as f64));
    oracle_obj.insert("merged_rounds_par".to_string(), num(par_merged as f64));
    oracle_obj.insert("routing_diffs".to_string(), num(diffs as f64));

    let mut rep = BenchReport::new("parallel_dispatch", smoke);
    rep.num("models_per_lane", M as f64)
        .set("saturated", Json::Obj(sat_obj))
        .set("oracle", Json::Obj(oracle_obj))
        .ns_per_slot("dispatch_single", single.elapsed / single.served.max(1) as f64 * 1e9)
        .ns_per_slot("dispatch_parallel", parallel.elapsed / parallel.served.max(1) as f64 * 1e9);
    rep.write()?;

    // correctness gates run in every mode (written AFTER the report so a
    // failing run still leaves its numbers behind)
    assert_eq!(
        diffs, 0,
        "parallel routing diverged from the sequential oracle"
    );
    assert!(
        parallel.stats.coalesced_rounds > 0,
        "grouped lanes must dispatch merged rounds in the parallel run"
    );
    // the throughput gate is sleep-dominated (both runs burn the same
    // modeled device time; only dispatch-thread overlap differs), so it
    // holds in smoke mode too
    assert!(
        ratio >= 1.5,
        "4 dispatch groups must serve >= 1.5x the single-thread loop, got {ratio:.2}x"
    );
    Ok(())
}
