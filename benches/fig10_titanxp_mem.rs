//! Bench: paper Figure 10 (Appendix B) — peak memory on the 12 GB
//! TITAN Xp profile. (The paper's own Appendix B notes its allocator
//! behaved inconsistently here; we reproduce the systematic model.)

use netfuse::devmodel::TITAN_XP;
use netfuse::figures::{self, FigOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = FigOpts::default();
    opts.device = TITAN_XP;
    println!("{}", figures::fig7(&opts)?);
    Ok(())
}
