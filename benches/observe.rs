//! Bench: observability-plane overhead (ADR-006).
//!
//! The same open-loop serving scenario — a 2-lane coalesce group plus a
//! standalone lane on a `ParallelDispatcher` — runs twice: once bare,
//! once with the full observability plane attached (stage tracing into
//! per-lane histograms, flight recorder, lane gauges, a `MetricsHub`,
//! and one live `ObsQuery` answered mid-run). Producer-observed
//! latencies diff the two.
//!
//! Gates:
//! - **every mode**: every submission gets exactly one byte-exact
//!   response in both runs (instrumentation must not change a byte);
//!   in the instrumented run the `ObsReport`'s counters must equal the
//!   run's final merged `IngressStats` field by field, and the stage
//!   histograms must hold exactly one fold per response per stage.
//! - **full mode only** (CI runs `--smoke`): mean producer latency
//!   with observability on <= 1.05x off — the <=5% overhead budget.
//!
//! Results (including per-stage mean nanoseconds from the merged
//! histograms) go to `BENCH_observe.json`.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use netfuse::coordinator::metrics::MetricsHub;
use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::{GroupSpec, LaneSpec, ParallelDispatcher};
use netfuse::coordinator::obs::{ObsHub, Stage};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch_parallel_observed, Envelope, Frame, FrameQueue, IngressBridge, IngressStats,
    LaneQos,
};
use netfuse::util::bench::report::BenchReport;
use netfuse::util::json::Json;
use netfuse::util::shard::Sharded;

/// The shared test scaffolding (seeded request builder) — outcome
/// verification uses the same payload-seeding scheme as the suites.
#[path = "../rust/tests/common/mod.rs"]
mod common;

/// models per lane (the group executor runs 2 * M slots)
const M: usize = 2;
const INNER: [usize; 1] = [4];
/// modeled device time per round — realistic enough that the per-seam
/// stamp copies and histogram folds must stay invisible next to it
const ROUND_COST: Duration = Duration::from_micros(200);
const FAR: Duration = Duration::from_secs(3600);

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 8192,
        max_wait: Duration::ZERO,
    }
}

fn seeded_at(id: u64, model: usize, j: usize) -> f32 {
    id as f32 * 1000.0 + model as f32 * 10.0 + j as f32
}

struct RunOut {
    mean: f64,
    p50: f64,
    p99: f64,
    served: usize,
    stats: IngressStats,
    /// per-stage (mean ns, count) from the merged histograms (obs run)
    stage_ns: Option<BTreeMap<String, (f64, u64)>>,
}

/// One serving run: `load` paced producer requests over the three
/// lanes. With `obs` the full plane is attached and, once every
/// response is back (but before shutdown), one `ObsQuery` is answered
/// live and checked against the final counters.
fn run(load: usize, pace: Duration, obs: bool) -> Result<RunOut> {
    let bert0 = EchoExecutor::new("bert", M, &INNER, ROUND_COST);
    let bert1 = EchoExecutor::new("bert", M, &INNER, ROUND_COST);
    let group = EchoExecutor::new("bert", 2 * M, &INNER, ROUND_COST);
    let solo = EchoExecutor::new("solo", M, &INNER, ROUND_COST);
    let mut d = ParallelDispatcher::new(
        vec![
            LaneSpec::new(&bert0, lane_config(), LaneQos::new(1, FAR)),
            LaneSpec::new(&bert1, lane_config(), LaneQos::new(1, FAR)),
            LaneSpec::new(&solo, lane_config(), LaneQos::new(1, FAR)),
        ],
        vec![GroupSpec::new(&group, &[0, 1])],
    )?;
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(d.parts() + 1));
    let bridge = IngressBridge::new(load + 16);
    let metrics = Arc::new(MetricsHub::new(d.parts()));
    let hub = obs.then(|| Arc::new(ObsHub::new(d.parts() + 1)));
    if let Some(h) = &hub {
        d.attach_metrics_hub(&metrics);
        h.attach_metrics(Arc::clone(&metrics));
        bridge.attach_obs(Arc::clone(h));
    }

    let mut submitted: HashMap<u64, (usize, Instant)> = HashMap::new();
    let mut arrived: Vec<(Frame, Instant)> = Vec::with_capacity(load);
    let mut report: Option<String> = None;
    let run_out: Result<()> = std::thread::scope(|s| {
        let runner = s.spawn(|| run_dispatch_parallel_observed(&mut d, &bridge, 4096, &stats));

        let reply = FrameQueue::new();
        let mut drain = |arrived: &mut Vec<(Frame, Instant)>| {
            while let Some(f) = reply.try_pop() {
                arrived.push((f, Instant::now()));
            }
        };
        for i in 0..load {
            let id = i as u64;
            let env = Envelope {
                lane: i % 3,
                client_id: id,
                req: common::seeded_request(id, i % M, &INNER),
                reply: reply.clone(),
            };
            if bridge.submit(env).is_err() {
                bridge.close();
                bail!("producer submit refused (bridge sized for the run)");
            }
            submitted.insert(id, (i % M, Instant::now()));
            drain(&mut arrived);
            std::thread::sleep(pace);
        }
        // drain the tail BEFORE the query: the report is taken at a
        // quiesced moment, so its counters must match shutdown's
        let deadline = Instant::now() + Duration::from_secs(30);
        while arrived.len() < load {
            if Instant::now() >= deadline {
                bridge.close(); // let the runner drain out before we bail
                bail!("tail stalled at {}/{load} responses", arrived.len());
            }
            drain(&mut arrived);
            std::thread::sleep(Duration::from_micros(50));
        }
        if let Some(h) = &hub {
            let q = FrameQueue::new();
            h.enqueue_query(7, q.clone());
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                if let Some(Frame::ObsReport { id: 7, json }) = q.try_pop() {
                    report = Some(json);
                    break;
                }
                if Instant::now() >= deadline {
                    bridge.close();
                    bail!("ObsQuery went unanswered on the live loop");
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        bridge.close();
        runner.join().expect("dispatch runner panicked")
    });
    run_out?;
    let stats = stats.read();

    // ---- post-join verification: nothing lost, nothing perturbed ----
    let mut lat = Vec::with_capacity(load);
    for (f, at) in &arrived {
        match f {
            Frame::Response { id, model_idx, data, .. } => {
                let Some((model, t0)) = submitted.remove(id) else {
                    bail!("id {id}: response never submitted, or served twice");
                };
                ensure!(*model_idx as usize == model, "id {id}: wrong model");
                for (j, &x) in data.iter().enumerate() {
                    ensure!(
                        x == seeded_at(*id, model, j),
                        "id {id} byte {j}: got {x} (observability changed a payload?)"
                    );
                }
                lat.push((*at - t0).as_secs_f64());
            }
            other => bail!("nothing may reject in this scenario: {other:?}"),
        }
    }
    ensure!(submitted.is_empty(), "{} requests lost", submitted.len());

    // the deterministic introspection gates (every mode)
    let stage_ns = match &hub {
        None => None,
        Some(h) => {
            let json = report.as_ref().expect("instrumented run must carry a report");
            let r = Json::parse(json).map_err(|e| anyhow::anyhow!("bad report JSON: {e:?}"))?;
            let pairs: [(&str, u64); 12] = [
                ("admitted", stats.admitted),
                ("lane_busy", stats.lane_busy),
                ("group_busy", stats.group_busy),
                ("invalid", stats.invalid),
                ("no_lane", stats.no_lane),
                ("shed", stats.shed),
                ("responses", stats.responses),
                ("rounds", stats.rounds),
                ("coalesced_rounds", stats.coalesced_rounds),
                ("round_errors", stats.round_errors),
                ("idle_naps_avoided", stats.idle_naps_avoided),
                ("ctrl_ops", stats.ctrl_ops),
            ];
            for (key, want) in pairs {
                ensure!(
                    r.get("stats").get(key).as_usize() == Some(want as usize),
                    "ObsReport stats.{key} diverged from the final counters \
                     ({:?} vs {want})",
                    r.get("stats").get(key)
                );
            }
            ensure!(
                r.get("metrics").get("completed_requests").as_usize() == Some(load),
                "MetricsHub aggregate missed responses"
            );
            // stage histograms: one fold per response per stage
            let stages = h.stages();
            let mut out = BTreeMap::new();
            for st in Stage::ALL {
                let (mut count, mut sum) = (0u64, 0u64);
                for lane in stages.lanes() {
                    count += lane.stage(st).count();
                    sum += lane.stage(st).sum_ns();
                }
                ensure!(
                    count as usize == load,
                    "stage {} folded {count} of {load} responses",
                    st.name()
                );
                out.insert(st.name().to_string(), (sum as f64 / count as f64, count));
            }
            Some(out)
        }
    };

    ensure!(!lat.is_empty(), "no latencies recorded");
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = lat.iter().sum::<f64>() / lat.len() as f64;
    Ok(RunOut {
        mean,
        p50: lat[lat.len() / 2],
        p99: lat[(lat.len() as f64 * 0.99) as usize - 1],
        served: lat.len(),
        stats,
        stage_ns,
    })
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "# observe: observability-plane overhead next to open-loop traffic{}\n",
        if smoke { " (SMOKE)" } else { "" }
    );

    let load = if smoke { 300 } else { 3000 };
    let pace = Duration::from_micros(if smoke { 200 } else { 400 });

    let off = run(load, pace, false)?;
    let on = run(load, pace, true)?;
    let inflation = on.mean / off.mean.max(1e-9);

    for (name, r) in [("obs off", &off), ("obs on ", &on)] {
        println!(
            "{name}: {} served, mean {:.0}us p50 {:.0}us p99 {:.0}us | {} rounds, {} merged",
            r.served,
            r.mean * 1e6,
            r.p50 * 1e6,
            r.p99 * 1e6,
            r.stats.rounds,
            r.stats.coalesced_rounds,
        );
    }
    println!("mean-latency inflation with observability on: {inflation:.3}x");
    if let Some(stages) = &on.stage_ns {
        for (name, (ns, count)) in stages {
            println!("  stage {name:<8} mean {ns:>10.0} ns  ({count} folds)");
        }
    }
    println!();

    let obj = |r: &RunOut| {
        let mut o = BTreeMap::new();
        o.insert("served".to_string(), num(r.served as f64));
        o.insert("mean_s".to_string(), num(r.mean));
        o.insert("p50_s".to_string(), num(r.p50));
        o.insert("p99_s".to_string(), num(r.p99));
        o.insert("rounds".to_string(), num(r.stats.rounds as f64));
        o.insert("merged_rounds".to_string(), num(r.stats.coalesced_rounds as f64));
        Json::Obj(o)
    };
    let mut rep = BenchReport::new("observe", smoke);
    rep.num("load", load as f64)
        .num("pace_us", pace.as_secs_f64() * 1e6)
        .num("mean_inflation", inflation)
        .set("off", obj(&off))
        .set("on", obj(&on));
    if let Some(stages) = &on.stage_ns {
        let mut o = BTreeMap::new();
        for (name, (ns, _)) in stages {
            o.insert(name.clone(), num(*ns));
        }
        rep.set("stage_mean_ns", Json::Obj(o));
        for (name, (ns, _)) in stages {
            rep.ns_per_slot(&format!("stage_{name}"), *ns);
        }
    }
    rep.write()?;

    // correctness gates (written AFTER the report so a failing run
    // still leaves its numbers behind); run() already enforced
    // byte-exact outcomes and report-vs-final counter equality
    assert_eq!(off.served, load, "bare run lost requests");
    assert_eq!(on.served, load, "instrumented run lost requests");
    assert!(on.stage_ns.is_some(), "instrumented run must produce stage data");
    // the overhead gate is full-mode only: smoke runs are too short
    // for a stable mean on shared CI runners
    if !smoke {
        assert!(
            inflation <= 1.05,
            "observability inflated mean latency by {inflation:.3}x (> 1.05x): \
             the plane's hot-path budget is 5%"
        );
    }
    Ok(())
}
