//! Bench: paper Figure 8 — the (Ap, Bm) hybrid sweep at 32 models:
//! sequential (1p,32m) ... concurrent (32p,1m), plus NETFUSE.

use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("NETFUSE_BENCH_FULL").is_ok();
    let mut opts = FigOpts::default();
    opts.m_sweep = vec![32];
    if !full {
        opts.models = vec!["resnext".into(), "xlnet".into()];
        opts.samples = 5;
    }
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("{}", figures::fig8(Some(&rt), &opts)?);
    Ok(())
}
