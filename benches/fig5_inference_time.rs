//! Bench: paper Figure 5 — mean inference time of Sequential / Concurrent
//! / NetFuse for a varying number of models (bs=1), on the V100 device
//! model AND measured on CPU PJRT with the mini models.
//!
//! Full sweep: NETFUSE_BENCH_FULL=1 cargo bench --bench fig5_inference_time

use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("NETFUSE_BENCH_FULL").is_ok();
    let mut opts = FigOpts::default();
    if !full {
        opts.m_sweep = vec![2, 8, 32];
        opts.samples = 5;
    }
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("{}", figures::fig5(Some(&rt), &opts)?);
    Ok(())
}
