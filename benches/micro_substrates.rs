//! Microbenchmarks for the coordinator's hot-path substrates: tensor
//! concat/stack/split (the batcher inner loop), weight-bank stacking,
//! JSON manifest parsing, and the PJRT round-trip. Used by the §Perf
//! pass to find and track L3 bottlenecks.

use netfuse::coordinator::service;
use netfuse::fuse;
use netfuse::runtime::Runtime;
use netfuse::tensor::Tensor;
use netfuse::util::bench::Bench;
use netfuse::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::new();
    let mut rng = Rng::new(1);

    // batcher inner loop: pack 32 CNN inputs on the channel axis
    let xs: Vec<Tensor> = (0..32).map(|_| Tensor::randn(&[1, 3, 16, 16], &mut rng)).collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    b.run("tensor/concat-ch 32x[1,3,16,16]", || {
        std::hint::black_box(Tensor::concat(&refs, 1).unwrap());
    });
    b.run("tensor/stack 32x[1,3,16,16]", || {
        std::hint::black_box(Tensor::stack(&refs).unwrap());
    });
    let big = Tensor::concat(&refs, 1)?;
    b.run("tensor/split 32 of [1,96,16,16]", || {
        std::hint::black_box(big.split(32, 1).unwrap());
    });
    let batch = Tensor::stack(&refs)?;
    b.run("tensor/swap01 [32,1,3,16,16]", || {
        std::hint::black_box(batch.swap01().unwrap());
    });

    // manifest parse (startup path)
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json")?;
    b.run("json/parse manifest", || {
        std::hint::black_box(netfuse::util::json::Json::parse(&manifest_text).unwrap());
    });

    // weight-bank stacking (fleet load path)
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    let g = rt.manifest.model("resnet")?.graph.clone();
    let banks = service::load_banks(&rt, "resnet", 8)?;
    let merged = fuse::merge(&g, 8)?;
    b.run("fuse/merge-weights resnet m=8", || {
        std::hint::black_box(fuse::weights::merge_weights(&merged, &banks).unwrap());
    });
    b.run("fuse/merge-plan resnet m=8", || {
        std::hint::black_box(fuse::merge(&g, 8).unwrap());
    });

    // PJRT round-trip (request hot path): one bert single inference
    let fleet = netfuse::coordinator::Fleet::load(&rt, "bert", 2, 1)?;
    let x = Tensor::randn(&fleet.request_shape(), &mut rng);
    b.run("runtime/bert single run", || {
        std::hint::black_box(fleet.single(0).run(&x).unwrap());
    });
    let xs2: Vec<Tensor> = (0..2).map(|_| Tensor::randn(&fleet.request_shape(), &mut rng)).collect();
    let refs2: Vec<&Tensor> = xs2.iter().collect();
    b.run("runtime/bert fused m=2 round", || {
        std::hint::black_box(
            fleet.run_round(netfuse::coordinator::StrategyKind::NetFuse, &refs2).unwrap(),
        );
    });
    Ok(())
}
