//! Bench: paper Figure 9 (Appendix B) — Figure 5 on the TITAN Xp device
//! model. Gains are smaller than V100 (fewer SMs = less parallel
//! headroom), matching the paper's observation.

use netfuse::devmodel::TITAN_XP;
use netfuse::figures::{self, FigOpts};

fn main() -> anyhow::Result<()> {
    let mut opts = FigOpts::default();
    opts.device = TITAN_XP;
    opts.measured = false; // CPU wall-clock is hardware-independent here
    println!("{}", figures::fig5(None, &opts)?);
    Ok(())
}
