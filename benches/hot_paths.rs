//! Bench: the SIMD round hot paths vs their strict scalar references,
//! plus sharded vs single-mutex metrics recording under N threads.
//!
//! Three parts, all offline:
//!
//! 1. **Pack / gather** — an 8-instance `[16, 256]` Channel megabatch
//!    (128 KiB staging buffer, the `RoundArena::pack_with` shape).
//!    Production `pack_full` (which scatters through
//!    `util::simd::scatter_rows`) races the strict per-element
//!    `simd::reference` kernels; same for the unpack-direction
//!    `gather_rows`. Gate (detected backends only): >= 1.5x the scalar
//!    reference in ns/slot. Under `RUST_PALLAS_FORCE_SCALAR=1` (or a
//!    scalar-only arch) the run is parity-only.
//! 2. **Frame codec** — the 4096-f32 payload encode/decode primitives
//!    (`extend_f32_le` / `extend_le_f32`) behind `Frame::encode_into`
//!    and `Frame::decode_payload`, vs the per-element reference; plus
//!    an untimed full-frame roundtrip equality check.
//! 3. **Metrics recording** — 4 threads hammering `record_request` +
//!    `record_round` through one shared `Mutex<MetricsCore>` vs a
//!    4-shard `Sharded<MetricsCore>` (one private shard per thread).
//!    Gate (every mode): sharded recording >= 2x the single-mutex
//!    throughput, and the merged read is exact (completed == total).
//!
//! Byte-parity asserts run in EVERY mode — the speedup gates never
//! trade correctness. Results go to `BENCH_hot_paths.json`.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use netfuse::coordinator::arena::{Layout, RoundArena};
use netfuse::coordinator::metrics::MetricsCore;
use netfuse::ingress::Frame;
use netfuse::tensor::Tensor;
use netfuse::util::bench::report::BenchReport;
use netfuse::util::bench::Bench;
use netfuse::util::json::Json;
use netfuse::util::rng::Rng;
use netfuse::util::shard::Sharded;
use netfuse::util::simd::{self, reference, Backend, Windows};

/// megabatch geometry: M instance windows of [OUTER, INNER] each
const M: usize = 8;
const OUTER: usize = 16;
const INNER: usize = 256;
const SLOT: usize = OUTER * INNER;
/// codec payload length (one Response tensor of shape [1, PAYLOAD])
const PAYLOAD: usize = 4096;
/// recording threads (matches the dispatch-thread count of the
/// parallel_dispatch bench topology)
const THREADS: usize = 4;

fn slot_window(i: usize) -> Windows {
    Windows {
        rows: OUTER,
        row_len: INNER,
        dst_offset: i * INNER,
        dst_stride: M * INNER,
        src_offset: 0,
        src_stride: INNER,
    }
}

fn seeded_inputs(rng: &mut Rng) -> Vec<Tensor> {
    (0..M)
        .map(|_| {
            let data: Vec<f32> = (0..SLOT).map(|_| rng.f32_range(-4.0, 4.0)).collect();
            Tensor::new(vec![OUTER, INNER], data).expect("input tensor")
        })
        .collect()
}

/// ns per instance window for a whole-megabatch op (M windows/iter).
fn ns_per_slot(mean_s: f64, slots_per_iter: usize) -> f64 {
    mean_s / slots_per_iter as f64 * 1e9
}

/// Best-of-3 wall time for one multi-threaded recording run of
/// `total` records spread over [`THREADS`] threads.
fn record_run(total: u64, one_thread: impl FnMut(usize, u64) + Copy + Send) -> f64 {
    let per = total / THREADS as u64;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                s.spawn(move || one_thread(t, per));
            }
        });
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() -> Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let backend = simd::backend();
    println!(
        "# hot_paths: SIMD pack/gather/codec + sharded metrics (backend {backend:?}){}\n",
        if smoke { " (SMOKE)" } else { "" }
    );
    let mut b = if smoke { Bench::quick() } else { Bench::new() };
    let mut rng = Rng::new(0x51D_D15B);

    // --- part 1: megabatch pack + gather -------------------------------
    let inputs = seeded_inputs(&mut rng);
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let mut arena = RoundArena::new(Layout::Channel, M, &[OUTER, INNER])?;
    let pack = b.run("pack: RoundArena::pack_full (simd scatter)", || {
        arena.pack_full(&refs).expect("pack");
    });
    let mut merged_ref = vec![0.0f32; M * SLOT];
    let pack_ref = b.run("pack: reference::copy_windows per slot", || {
        for (i, x) in inputs.iter().enumerate() {
            reference::copy_windows(&mut merged_ref, x.data(), slot_window(i));
        }
    });
    assert_eq!(
        arena.merged().data(),
        &merged_ref[..],
        "simd pack must be byte-identical to the reference pack"
    );

    let merged = arena.merged().data();
    let mut out = vec![0.0f32; SLOT];
    let gather = b.run("gather: simd::gather_rows per slot", || {
        for i in 0..M {
            simd::gather_rows(&mut out, merged, i * INNER, M * INNER, OUTER, INNER);
            std::hint::black_box(out[0]);
        }
    });
    let mut out_ref = vec![0.0f32; SLOT];
    let gather_ref = b.run("gather: reference::copy_windows per slot", || {
        for i in 0..M {
            let w = Windows {
                rows: OUTER,
                row_len: INNER,
                dst_offset: 0,
                dst_stride: INNER,
                src_offset: i * INNER,
                src_stride: M * INNER,
            };
            reference::copy_windows(&mut out_ref, merged, w);
            std::hint::black_box(out_ref[0]);
        }
    });
    assert_eq!(out, out_ref, "simd gather must be byte-identical to the reference gather");

    // --- part 2: frame payload codec -----------------------------------
    let payload: Vec<f32> = (0..PAYLOAD).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let mut enc = Vec::with_capacity(PAYLOAD * 4);
    let encode = b.run("encode: simd::extend_f32_le 4096 f32", || {
        enc.clear();
        simd::extend_f32_le(&mut enc, &payload);
    });
    let mut enc_ref = Vec::with_capacity(PAYLOAD * 4);
    let encode_ref = b.run("encode: reference per-element to_le_bytes", || {
        enc_ref.clear();
        reference::extend_f32_le(&mut enc_ref, &payload);
    });
    assert_eq!(enc, enc_ref, "simd encode must be byte-identical to the reference");

    let mut dec = Vec::with_capacity(PAYLOAD);
    let decode = b.run("decode: simd::extend_le_f32 4096 f32", || {
        dec.clear();
        simd::extend_le_f32(&mut dec, &enc);
    });
    let mut dec_ref = Vec::with_capacity(PAYLOAD);
    let decode_ref = b.run("decode: reference per-chunk from_le_bytes", || {
        dec_ref.clear();
        reference::extend_le_f32(&mut dec_ref, &enc_ref);
    });
    assert_eq!(dec, dec_ref, "simd decode must be byte-identical to the reference");
    assert_eq!(dec, payload, "codec roundtrip must be the identity");

    // untimed: the full frame path built on those primitives roundtrips
    let frame = Frame::Response {
        id: 7,
        lane: 1,
        model_idx: 0,
        latency: 0.0125,
        shape: vec![1, PAYLOAD],
        data: payload.clone(),
    };
    let mut wire = Vec::new();
    frame.encode_into(&mut wire);
    assert_eq!(
        Frame::decode_payload(&wire[4..])?,
        frame,
        "frame encode/decode roundtrip through the simd codec"
    );

    // --- part 3: sharded vs single-mutex recording ---------------------
    let total: u64 = if smoke { 50_000 } else { 400_000 };
    let slo = Some(0.010);

    let mutexed = Arc::new(Mutex::new(MetricsCore::default()));
    let mutex_s = record_run(total, |t, per| {
        for i in 0..per {
            let lat = 0.001 + (t as u64 * per + i) as f64 * 1e-8;
            let mut m = mutexed.lock().unwrap();
            m.record_request(lat, slo);
            m.record_round(lat);
        }
    });
    assert_eq!(mutexed.lock().unwrap().completed_requests % total, 0);

    let sharded: Arc<Sharded<MetricsCore>> = Arc::new(Sharded::new(THREADS));
    let shard_s = {
        let sharded = &sharded;
        record_run(total, move |t, per| {
            let h = Sharded::register(sharded);
            for i in 0..per {
                let lat = 0.001 + (t as u64 * per + i) as f64 * 1e-8;
                let mut m = h.lock();
                m.record_request(lat, slo);
                m.record_round(lat);
            }
        })
    };
    // merge-on-read exactness: the last of the 3 runs recorded `total`
    // more requests; the merged view must account for every one
    let agg = sharded.read();
    assert_eq!(agg.completed_requests, 3 * total, "sharded merge lost records");

    let mutex_rps = total as f64 / mutex_s;
    let shard_rps = total as f64 / shard_s;
    let record_ratio = shard_rps / mutex_rps.max(1e-9);
    println!(
        "\nrecord x{THREADS}: mutex {mutex_rps:.0}/s, sharded {shard_rps:.0}/s \
         ({record_ratio:.2}x)"
    );

    // --- BENCH_hot_paths.json ------------------------------------------
    let pack_ratio = pack_ref.mean / pack.mean.max(1e-12);
    let gather_ratio = gather_ref.mean / gather.mean.max(1e-12);
    let encode_ratio = encode_ref.mean / encode.mean.max(1e-12);
    let decode_ratio = decode_ref.mean / decode.mean.max(1e-12);
    println!(
        "speedups vs scalar reference: pack {pack_ratio:.2}x, gather {gather_ratio:.2}x, \
         encode {encode_ratio:.2}x, decode {decode_ratio:.2}x"
    );

    let mut rep = BenchReport::new("hot_paths", smoke);
    rep.set("backend", Json::Str(format!("{backend:?}")))
        .num("threads", THREADS as f64)
        .num("pack_ratio", pack_ratio)
        .num("gather_ratio", gather_ratio)
        .num("encode_ratio", encode_ratio)
        .num("decode_ratio", decode_ratio)
        .num("record_ratio", record_ratio)
        .num("record_mutex_per_s", mutex_rps)
        .num("record_sharded_per_s", shard_rps)
        .ns_per_slot("pack_simd", ns_per_slot(pack.mean, M))
        .ns_per_slot("pack_reference", ns_per_slot(pack_ref.mean, M))
        .ns_per_slot("gather_simd", ns_per_slot(gather.mean, M))
        .ns_per_slot("gather_reference", ns_per_slot(gather_ref.mean, M))
        .ns_per_slot("encode_simd", ns_per_slot(encode.mean, 1))
        .ns_per_slot("encode_reference", ns_per_slot(encode_ref.mean, 1))
        .ns_per_slot("decode_simd", ns_per_slot(decode.mean, 1))
        .ns_per_slot("decode_reference", ns_per_slot(decode_ref.mean, 1));
    rep.write()?;

    // speed gates run AFTER the report so a failing run leaves numbers
    if backend == Backend::Scalar {
        println!("scalar backend pinned: parity gates only, speedup gates skipped");
    } else {
        assert!(
            pack_ratio >= 1.5,
            "simd pack must beat the scalar reference >= 1.5x, got {pack_ratio:.2}x"
        );
        assert!(
            gather_ratio >= 1.5,
            "simd gather must beat the scalar reference >= 1.5x, got {gather_ratio:.2}x"
        );
    }
    assert!(
        record_ratio >= 2.0,
        "sharded recording must beat the single mutex >= 2x at {THREADS} threads, \
         got {record_ratio:.2}x"
    );
    Ok(())
}
