//! Ablation: refmt (reshape/transpose fix-up) elision — DESIGN.md calls
//! out the fix-up ops Algorithm 1 inserts on merge-dimension conflicts.
//! This bench counts them per merged model and shows the effect of the
//! inverse-pair elision pass on graph size and estimated cost.

use netfuse::devmodel::V100;
use netfuse::fuse;
use netfuse::rewriter;
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("# refmt ablation: fix-up ops inserted by Algorithm 1 per merged graph");
    println!("# model      M   nodes  refmts  after-elision  est-cost-delta");
    for model in ["resnet", "resnext", "bert", "xlnet"] {
        let g = rt.manifest.model(model)?.graph.clone();
        for m in [2usize, 8, 32] {
            let merged = fuse::merge(&g, m)?;
            let refmts = merged.nodes.iter().filter(|n| n.kind == "refmt").count();
            let opt = fuse::elide_refmt_pairs(&merged);
            let refmts_after = opt.nodes.iter().filter(|n| n.kind == "refmt").count();
            let c0 = rewriter::graph_cost(&V100, &merged, 1);
            let c1 = rewriter::graph_cost(&V100, &opt, 1);
            println!(
                "{:<10} {:>3} {:>6} {:>7} {:>14} {:>14.2}%",
                model,
                m,
                merged.nodes.len(),
                refmts,
                refmts_after,
                (c0 - c1) / c0 * 100.0
            );
        }
    }
    Ok(())
}
