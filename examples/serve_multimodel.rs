//! End-to-end serving driver (DESIGN.md "End-to-end validation").
//!
//! Loads a real fleet of M fine-tuned model instances from the AOT
//! artifacts and serves batched requests through the full coordinator
//! stack — workload generator → router → batcher → strategy → responses
//! — under all four execution strategies, reporting latency and
//! throughput for each. This is the serving-paper analog of "load a
//! small real model and serve batched requests".
//!
//! ```bash
//! cargo run --release --example serve_multimodel -- [model] [m] [rounds]
//! ```

use netfuse::coordinator::server::{Server, ServerConfig};
use netfuse::coordinator::workload::Workload;
use netfuse::coordinator::{Fleet, StrategyKind};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("bert");
    let m: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let rounds: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!(
        "serving {model} x{m}, {rounds} rounds per strategy on {}",
        rt.platform()
    );
    let fleet = Fleet::load(&rt, model, m, 1)?;

    let strategies = [
        StrategyKind::Sequential,
        StrategyKind::Concurrent,
        StrategyKind::Hybrid { procs: (m / 4).max(1) },
        StrategyKind::NetFuse,
    ];

    println!(
        "\n{:<12} {:>12} {:>12} {:>12} {:>12}",
        "strategy", "round p50", "round p99", "req p50", "req/s"
    );
    let mut results = Vec::new();
    for strategy in strategies {
        let mut server =
            Server::new(&fleet, ServerConfig { strategy, ..Default::default() });
        let mut workload = Workload::new(m, &fleet.request_shape(), 500.0, 42);
        let served = server.run_rounds(rounds, || workload.round())?;
        assert_eq!(served, rounds * m, "all requests must be answered");
        let met = &server.metrics;
        println!(
            "{:<12} {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>12.1}",
            strategy.to_string(),
            met.round_latency.p50() * 1e3,
            met.round_latency.p99() * 1e3,
            met.request_latency.p50() * 1e3,
            met.throughput(),
        );
        results.push((strategy, met.round_latency.p50()));
    }

    // the paper's headline: the merged executable beats round-robin
    let seq = results
        .iter()
        .find(|(s, _)| *s == StrategyKind::Sequential)
        .unwrap()
        .1;
    let nf = results
        .iter()
        .find(|(s, _)| *s == StrategyKind::NetFuse)
        .unwrap()
        .1;
    println!(
        "\nNETFUSE round-latency speedup vs sequential: {:.2}x",
        seq / nf
    );
    Ok(())
}
