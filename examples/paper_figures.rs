//! Regenerate every table and figure in the paper's evaluation (§5 +
//! Appendix B) and write them under `results/`.
//!
//! ```bash
//! cargo run --release --example paper_figures            # full sweep
//! cargo run --release --example paper_figures -- --quick # CI-speed
//! ```
//!
//! Output files (also summarized to stdout):
//!   results/fig2.txt            rewriter baseline vs Algorithm 1
//!   results/fig5.txt            inference time vs #models (V100 + CPU)
//!   results/fig6.txt            BERT batch-size sweep
//!   results/fig7.txt            peak memory (V100)
//!   results/fig8.txt            hybrid configurations
//!   results/fig9.txt            inference time (TITAN Xp)
//!   results/fig10.txt           peak memory (TITAN Xp)
//!   results/merge_overhead.txt  §4 merge cost
//!   results/headline.txt        §5.2 headline speedups

use std::fs;
use std::path::Path;

use netfuse::devmodel::{self, sim, TITAN_XP, V100};
use netfuse::figures::{self, FigOpts};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut opts = if quick { FigOpts::quick() } else { FigOpts::default() };
    let rt = Runtime::open(Path::new("artifacts"))?;
    fs::create_dir_all("results")?;

    let mut save = |name: &str, body: &str| -> anyhow::Result<()> {
        fs::write(format!("results/{name}.txt"), body)?;
        println!("=== {name} ===\n{body}");
        Ok(())
    };

    save("fig2", &figures::fig2()?)?;
    save("fig5", &figures::fig5(Some(&rt), &opts)?)?;
    save("fig6", &figures::fig6(Some(&rt), &opts)?)?;
    {
        let mut s = figures::fig7(&opts)?;
        s.push('\n');
        s.push_str(&figures::fig7_measured(&rt, &opts)?);
        save("fig7", &s)?;
    }
    save("fig8", &figures::fig8(Some(&rt), &opts)?)?;
    {
        let mut o = opts.clone();
        o.device = devmodel::TITAN_XP;
        o.measured = false;
        save("fig9", &figures::fig5(None, &o)?)?;
        save("fig10", &figures::fig7(&o)?)?;
    }
    save("merge_overhead", &figures::merge_overhead(&rt, &opts)?)?;

    // §5.2 headline numbers: max NETFUSE speedup per model
    let mut headline = String::from(
        "# §5.2 headline: max NETFUSE speedup vs best memory-fitting baseline\n\
         # (paper: 2.6x / 3.4x / 2.7x / 3.6x on V100; ~3.0x max on TITAN Xp)\n",
    );
    for dev in [V100, TITAN_XP] {
        for model in figures::MODELS {
            let mut best = 0.0f64;
            let mut best_m = 0;
            for &m in &opts.m_sweep {
                if m < 2 {
                    continue;
                }
                let s = sim::speedup_vs_best_baseline(&dev, model, m, 1)?;
                if s > best {
                    best = s;
                    best_m = m;
                }
            }
            headline.push_str(&format!(
                "{:<8} {:<8} {:.2}x (at M={})\n",
                dev.name, model, best, best_m
            ));
        }
    }
    save("headline", &headline)?;
    println!("wrote results/*.txt");
    Ok(())
}
