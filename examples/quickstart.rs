//! Quickstart: merge two fine-tuned BERT instances with NETFUSE and show
//! the merged executable returns exactly the per-model results.
//!
//! This example runs the **Pallas-kernel** lowering of the model
//! (`*_pallas` artifacts): the batched-matmul / group-norm hot-spots in
//! the HLO executed here come from `python/compile/kernels/*.py`.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use netfuse::coordinator::{Fleet, StrategyKind};
use netfuse::runtime::Runtime;
use netfuse::tensor::Tensor;
use netfuse::util::rng::Rng;
use netfuse::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());

    // A fleet = M fine-tuned instances of one architecture. `_pallas`
    // selects the artifacts lowered through the Layer-1 Pallas kernels.
    let m = 4;
    let fleet = Fleet::load_with(&rt, "bert", m, 1, "_pallas")?;
    println!("loaded bert x{m} (merged layout: {})", fleet.layout);

    // one request per instance — different inputs, different weights
    let mut rng = Rng::new(7);
    let xs: Vec<Tensor> = (0..m)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();

    // warm both executables (first call pays compilation/upload costs)
    fleet.run_round(StrategyKind::Sequential, &refs)?;
    fleet.run_round(StrategyKind::NetFuse, &refs)?;

    // baseline: each instance separately
    let t = std::time::Instant::now();
    let singles = fleet.run_round(StrategyKind::Sequential, &refs)?;
    let t_seq = t.elapsed().as_secs_f64();

    // NETFUSE: one merged executable
    let t = std::time::Instant::now();
    let fused = fleet.run_round(StrategyKind::NetFuse, &refs)?;
    let t_nf = t.elapsed().as_secs_f64();

    for (i, (a, b)) in singles.iter().zip(&fused).enumerate() {
        let err = a.max_abs_diff(b)?;
        println!("instance {i}: max |single - fused| = {err:.2e}");
        assert!(err < 1e-3, "merged outputs must match per-model outputs");
    }
    println!(
        "sequential: {}   netfuse: {}   (one warm round; see benches for statistics)",
        fmt_secs(t_seq),
        fmt_secs(t_nf)
    );
    println!("quickstart OK");
    Ok(())
}
