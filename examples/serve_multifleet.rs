//! Multi-tenant serving driver: several fleets on ONE machine, one
//! shared machine-sized `WorkerPool`, fair round-ready dispatch via
//! `MultiServer` (the paper's many-fleets-per-GPU setting, §5).
//!
//! Loads a bert fleet (NETFUSE strategy — merged executable) and a
//! resnet fleet (Hybrid strategy — chunked workers on the shared pool)
//! and serves interleaved traffic through both lanes. This driver
//! dispatches lanes serially; the double-buffered arena's cross-round
//! overlap needs concurrent round drivers (see `benches/multi_fleet.rs`
//! for that measurement).
//!
//! ```bash
//! cargo run --release --example serve_multifleet -- [m] [rounds]
//! ```

use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::pool::WorkerPool;
use netfuse::coordinator::server::{Admit, ServerConfig};
use netfuse::coordinator::workload::Workload;
use netfuse::coordinator::{Fleet, StrategyKind};
use netfuse::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let m: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let rounds: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);

    let rt = Runtime::open(std::path::Path::new("artifacts"))?;
    // ONE pool for the whole box: every fleet dispatches onto it
    let pool = WorkerPool::machine_sized();
    println!(
        "multi-fleet serving on {}: bert x{m} (netfuse) + resnet x{m} (hybrid), \
         shared pool of {} workers, {rounds} rounds",
        rt.platform(),
        pool.workers()
    );

    let bert = Fleet::load_with_pool(&rt, "bert", m, 1, "", pool.clone())?;
    let resnet = Fleet::load_with_pool(&rt, "resnet", m, 1, "", pool.clone())?;

    let mut multi = MultiServer::new();
    let lane_a = multi.add_lane(
        &bert,
        ServerConfig { strategy: StrategyKind::NetFuse, ..Default::default() },
    );
    let lane_b = multi.add_lane(
        &resnet,
        ServerConfig {
            strategy: StrategyKind::Hybrid { procs: (m / 2).max(1) },
            ..Default::default()
        },
    );

    let mut wa = Workload::new(m, &bert.request_shape(), 500.0, 42);
    let mut wb = Workload::new(m, &resnet.request_shape(), 500.0, 43);
    let mut buf = Vec::new();
    for _ in 0..rounds {
        for req in wa.round() {
            anyhow::ensure!(multi.offer(lane_a, req)? == Admit::Queued, "bert queue full");
        }
        for req in wb.round() {
            anyhow::ensure!(multi.offer(lane_b, req)? == Admit::Queued, "resnet queue full");
        }
        // fair round-ready dispatch across lanes
        while multi.dispatch_next(&mut buf)?.is_some() {}
        buf.clear();
    }
    multi.drain(&mut buf)?;

    for (name, lane) in [("bert", lane_a), ("resnet", lane_b)] {
        let met = &multi.lane(lane).metrics;
        println!("{name:<8} {}", met.report_line());
        println!(
            "{name:<8} served {} requests at {:.1} req/s (p99 {:.2}ms)",
            met.completed_requests,
            met.throughput(),
            met.request_latency.p99() * 1e3,
        );
    }
    println!(
        "shared pool workers after serving: {} (one thread set for both fleets)",
        pool.workers()
    );
    Ok(())
}
