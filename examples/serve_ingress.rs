//! Open-loop ingress demo: TCP clients -> frames -> `IngressBridge` ->
//! QoS-scheduled `MultiServer` -> response frames.
//!
//! Four producer threads each hold their own TCP connection and replay
//! one shard of an open-loop Poisson arrival stream (the shards
//! superpose to the requested rate). Two lanes with different QoS:
//!
//! - `interactive` — WDRR weight 3, 25ms SLO, 75% of the traffic;
//! - `batch`       — WDRR weight 1, 250ms SLO.
//!
//! One dispatch thread owns the `MultiServer` and runs
//! `ingress::run_dispatch`: admission (with arrival re-stamping),
//! WDRR + SLO-boost lane picks, and response routing back through each
//! connection's reply queue.
//!
//! The lanes are in-process echo executors with a fixed modeled device
//! time, so the demo runs without AOT artifacts — swap in
//! `Fleet::load_with_pool` lanes to serve the real thing; every other
//! line stays identical.
//!
//! ```bash
//! cargo run --release --example serve_ingress -- [horizon_ms] [rate_rps]
//! ```

use std::net::TcpListener;
use std::time::Duration;

use anyhow::Result;

use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::service::RoundExecutor;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch, serve_conn, Frame, IngressBridge, LaneQos, LoadGen, TcpTransport, TrafficShape,
    Transport, TransportRx, TransportTx,
};

const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
const PRODUCERS: usize = 4;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let horizon_ms: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1200.0);
    let horizon = Duration::from_millis(horizon_ms);

    // in-process echo lanes (EchoExecutor) so the demo runs without AOT
    // artifacts; swap in `Fleet::load_with_pool` lanes to serve real HLO
    let interactive = EchoExecutor::new("interactive", M, &[4], Duration::from_micros(200));
    let batch = EchoExecutor::new("batch", M, &[4], Duration::from_micros(200));

    let mut multi = MultiServer::new();
    let cfg = ServerConfig {
        strategy: StrategyKind::Sequential,
        queue_cap: 256,
        max_wait: Duration::from_millis(2),
    };
    multi.add_lane_qos(&interactive, cfg.clone(), LaneQos::new(3, Duration::from_millis(25)));
    multi.add_lane_qos(&batch, cfg, LaneQos::new(1, Duration::from_millis(250)));
    let bridge = IngressBridge::new(1024);

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!(
        "serving 2 QoS lanes (interactive w=3 slo=25ms, batch w=1 slo=250ms) \
         on {addr}; {PRODUCERS} open-loop producers at {rate:.0} req/s for {horizon:?}"
    );

    // 75% of arrivals to the interactive lane
    let gen = LoadGen::new(TrafficShape::Poisson { rate }, &[(M, 3.0), (M, 1.0)], 0xD00D)?;
    let shards = gen.shards(PRODUCERS);

    let (stats, sent, ok, rejected) = std::thread::scope(|s| {
        // accept exactly one connection per producer, wire each to the
        // bridge (reader thread parses frames, writer drains replies)
        let accept = s.spawn(|| {
            (0..PRODUCERS)
                .map(|_| {
                    let (stream, _) = listener.accept().expect("accept");
                    let t = TcpTransport::from_stream(stream).expect("tcp transport");
                    serve_conn(bridge.clone(), Box::new(t)).expect("serve_conn")
                })
                .collect::<Vec<_>>()
        });

        // THE dispatch thread: sole owner of the MultiServer
        let multi_ref = &mut multi;
        let bridge_ref = &bridge;
        let dispatch = s.spawn(move || run_dispatch(multi_ref, bridge_ref));

        // producers: one TCP connection each, sender + receiver halves
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for shard in shards {
            let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr).expect("connect"));
            let (mut tx, mut rx) = t.split().expect("split");
            receivers.push(s.spawn(move || {
                let (mut ok, mut rejected) = (0u64, 0u64);
                loop {
                    match rx.recv() {
                        Ok(Some(Frame::Response { .. })) => ok += 1,
                        Ok(Some(Frame::Reject { .. })) => rejected += 1,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return (ok, rejected),
                    }
                }
            }));
            senders.push(s.spawn(move || {
                let sent = shard.drive(horizon, |a| {
                    let _ = tx.send(&Frame::Request {
                        id: a.id,
                        lane: a.lane as u32,
                        model_idx: a.model_idx as u32,
                        shape: INPUT_SHAPE.to_vec(),
                        data: vec![0.5; 4],
                    });
                });
                let _ = tx.send(&Frame::Eos);
                sent
            }));
        }

        let sent: u64 = senders.into_iter().map(|t| t.join().unwrap()).sum();
        let conns = accept.join().unwrap();
        bridge.close();
        let stats_res = dispatch.join().unwrap();
        for c in conns {
            c.shutdown();
        }
        let (mut ok, mut rejected) = (0u64, 0u64);
        for r in receivers {
            let (o, j) = r.join().unwrap();
            ok += o;
            rejected += j;
        }
        (stats_res, sent, ok, rejected)
    });
    let stats = stats?;

    println!(
        "\nopen loop done: {sent} sent -> {ok} responses + {rejected} rejects \
         ({} rounds, {} admitted, {} lane-busy, {} invalid)",
        stats.rounds, stats.admitted, stats.lane_busy, stats.invalid
    );
    for i in 0..multi.lanes() {
        let met = &multi.lane(i).metrics;
        let qos = multi.qos(i);
        println!("{}", met.report_line());
        println!(
            "  lane {i} ({}): served {} at {:.0} req/s | p99 {:.2}ms vs slo {:.0}ms \
             -> {} SLO violations",
            multi.lane(i).fleet().name(),
            met.completed_requests,
            met.throughput(),
            met.request_latency.p99() * 1e3,
            qos.slo.as_secs_f64() * 1e3,
            met.slo_violations,
        );
    }
    Ok(())
}
