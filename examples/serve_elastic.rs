//! Elastic-topology demo: TCP clients -> `IngressBridge` -> partitioned
//! dispatch threads, with a live **operator** reshaping the topology
//! mid-traffic through `TopologyController` (ADR-005).
//!
//! The serving side starts as a 2-lane `bert` coalesce group
//! (partition 0) + a standalone `solo` lane (partition 1) + one spare
//! partition. Open-loop producers drive Poisson traffic at the three
//! construction-time lanes for the whole run while the operator, on its
//! own TCP connection:
//!
//! 1. **adds** a fresh lane (lands on the spare partition) and serves a
//!    burst through it;
//! 2. **hot-swaps** the lane's weights (bounded pause, printed) and
//!    serves a second burst — echoed outputs shift by
//!    `tag * SWAP_SCALE`, proving the new weights answer;
//! 3. **removes** the lane (quiesce: drain, then excise) — follow-up
//!    frames to the dead global id come back as typed `NoLane` rejects,
//!    never silent drops.
//!
//! After every control-plane step the example prints the epoch-stamped
//! lane table (`TopologySnapshot`), and at exit the merged-round
//! counts, showing the coalesce group kept merging throughout.
//!
//! The observability plane (ADR-006) is attached: after each control
//! op the operator also sends `Frame::ObsQuery` down the same TCP
//! connection and prints the live `ObsReport` — the report's own
//! epoch-stamped lane table with each lane's per-stage latency
//! breakdown (queue/pack/execute/scatter/write p99), plus merged
//! counters and flight-recorder depth — all answered by a dispatch
//! thread between rounds, mid-churn.
//!
//! The lanes are in-process echo executors, so the demo runs without
//! AOT artifacts — swap in `Fleet::load_with_pool` lanes to serve the
//! real thing; every other line stays identical.
//!
//! ```bash
//! cargo run --release --example serve_elastic -- [horizon_ms] [rate_rps]
//! ```

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};

use netfuse::coordinator::control::{ControlPlane, TopologyController};
use netfuse::coordinator::metrics::MetricsHub;
use netfuse::coordinator::mock::{EchoExecutor, SWAP_SCALE};
use netfuse::coordinator::obs::ObsHub;
use netfuse::coordinator::multi::{
    GroupSpec, LaneSpec, ParallelDispatcher, TopologySnapshot,
};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch_elastic, serve_conn, Frame, IngressBridge, IngressStats, LaneQos, LoadGen,
    RejectCode, TcpTransport, TrafficShape, Transport, TransportRx, TransportTx,
};
use netfuse::util::json::Json;
use netfuse::util::shard::Sharded;

const M: usize = 2;
const INPUT_SHAPE: [usize; 2] = [1, 4];
const PRODUCERS: usize = 2;
const BURST: usize = 10;
const SWAP_TAG: u64 = 7;
const ACK: Duration = Duration::from_secs(5);

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 1024,
        max_wait: Duration::from_millis(1),
    }
}

fn qos() -> LaneQos {
    LaneQos::new(1, Duration::from_millis(250))
}

/// Render a live `ObsReport`: the introspection plane's own view of
/// the topology (epoch, lane gauges) plus each lane's stage-latency
/// breakdown from the merged histograms.
fn print_obs(what: &str, r: &Json) {
    println!(
        "[epoch {:>2}] obs after {what}: {} responses over {} rounds ({} merged), \
         recorder holds {} of {} events",
        r.get("epoch").as_i64().unwrap_or(-1),
        r.get("stats").get("responses").as_i64().unwrap_or(0),
        r.get("stats").get("rounds").as_i64().unwrap_or(0),
        r.get("stats").get("coalesced_rounds").as_i64().unwrap_or(0),
        r.get("recorder").get("retained").as_i64().unwrap_or(0),
        r.get("recorder").get("recorded").as_i64().unwrap_or(0),
    );
    for lane in r.get("lanes").as_arr().unwrap_or(&[]) {
        print!(
            "    lane {} [{} p{}s{}] pending {:>2} | stage p99 us:",
            lane.get("global").as_i64().unwrap_or(-1),
            lane.get("life").as_str().unwrap_or("?"),
            lane.get("part").as_i64().unwrap_or(-1),
            lane.get("local").as_i64().unwrap_or(-1),
            lane.get("pending").as_i64().unwrap_or(0),
        );
        for st in ["queue", "pack", "execute", "scatter", "write"] {
            let ns = lane.get("stages").get(st).get("p99_ns").as_f64().unwrap_or(0.0);
            print!(" {st} {:.0}", ns / 1e3);
        }
        println!();
    }
}

fn print_topo(what: &str, snap: &TopologySnapshot) {
    println!("[epoch {:>2}] {what}", snap.epoch);
    for (g, loc) in snap.lanes.iter().enumerate() {
        match loc {
            Some((p, l)) => println!("    lane {g} -> partition {p} slot {l}"),
            None => println!("    lane {g} -> (unmapped: rejects NoLane)"),
        }
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let horizon_ms: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(800.0);
    let horizon = Duration::from_millis(horizon_ms);
    let step = horizon / 5; // operator pacing between control-plane ops

    // in-process echo lanes so the demo runs without AOT artifacts
    let cost = Duration::from_micros(200);
    let bert0 = EchoExecutor::new("bert", M, &[4], cost);
    let bert1 = EchoExecutor::new("bert", M, &[4], cost);
    let group = EchoExecutor::new("bert", 2 * M, &[4], cost);
    let solo = EchoExecutor::new("solo", M, &[4], cost);
    let fresh = EchoExecutor::new("fresh", M, &[4], cost)
        .with_swap_cost(Duration::from_micros(500));

    let mut d = ParallelDispatcher::new(
        vec![
            LaneSpec::new(&bert0, lane_config(), qos()),
            LaneSpec::new(&bert1, lane_config(), qos()),
            LaneSpec::new(&solo, lane_config(), qos()),
        ],
        vec![GroupSpec::new(&group, &[0, 1])],
    )?;
    d.add_spare_part(); // the control plane installs into this one
    let plane = Arc::new(ControlPlane::for_dispatcher(&d));
    let ctl = TopologyController::new(d.topology_handle(), Arc::clone(&plane));
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(d.parts() + 1));
    let bridge = IngressBridge::new(1024);

    // observability plane (ADR-006): stage tracing + flight recorder +
    // live ObsQuery, attached before the dispatch threads start
    let metrics = Arc::new(MetricsHub::new(d.parts()));
    d.attach_metrics_hub(&metrics);
    let hub = Arc::new(ObsHub::new(d.parts() + 1));
    hub.attach_metrics(Arc::clone(&metrics));
    bridge.attach_obs(Arc::clone(&hub));

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!(
        "serving bert x2 (coalesced) + solo on {addr}; {PRODUCERS} open-loop \
         producers at {rate:.0} req/s for {horizon:?}, operator churn every {step:?}"
    );
    print_topo("initial topology", &ctl.snapshot());

    let gen = LoadGen::new(
        TrafficShape::Poisson { rate },
        &[(M, 1.0), (M, 1.0), (M, 1.0)],
        0xE1A57,
    )?;
    let shards = gen.shards(PRODUCERS);

    let (sent, ok, rejected, op_report) = std::thread::scope(|s| {
        let accept = s.spawn(|| {
            (0..PRODUCERS + 1)
                .map(|_| {
                    let (stream, _) = listener.accept().expect("accept");
                    let t = TcpTransport::from_stream(stream).expect("tcp transport");
                    serve_conn(bridge.clone(), Box::new(t)).expect("serve_conn")
                })
                .collect::<Vec<_>>()
        });

        // the dispatch side: router + one thread per partition, control
        // commands applied between rounds
        let d_ref = &mut d;
        let bridge_ref = &bridge;
        let stats_ref = &stats;
        let plane_ref = &plane;
        let runner =
            s.spawn(move || run_dispatch_elastic(d_ref, bridge_ref, 1024, stats_ref, plane_ref));

        // the operator: scripted add -> swap -> remove on its own conn
        let op = {
            let ctl = &ctl;
            let fresh = &fresh;
            s.spawn(move || -> Result<String> {
                let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr)?);
                let (mut tx, mut rx) = t.split()?;
                let mut id = 0u64;
                let mut burst = |tx: &mut Box<dyn TransportTx>,
                                 rx: &mut Box<dyn TransportRx>,
                                 lane: usize,
                                 n: usize|
                 -> Result<(u64, u64, f32)> {
                    let (mut ok, mut no_lane, mut first) = (0u64, 0u64, 0.0f32);
                    for i in 0..n {
                        tx.send(&Frame::Request {
                            id,
                            lane: lane as u32,
                            model_idx: (i % M) as u32,
                            shape: INPUT_SHAPE.to_vec(),
                            data: vec![1.0; 4],
                        })?;
                        id += 1;
                    }
                    for _ in 0..n {
                        match rx.recv()? {
                            Some(Frame::Response { data, .. }) => {
                                if ok == 0 {
                                    first = data[0];
                                }
                                ok += 1;
                            }
                            Some(Frame::Reject { code: RejectCode::NoLane, .. }) => no_lane += 1,
                            other => anyhow::bail!("operator got {other:?}"),
                        }
                    }
                    Ok((ok, no_lane, first))
                };
                // live introspection on the same connection: a dispatch
                // thread answers between rounds with the full report
                let observe = |tx: &mut Box<dyn TransportTx>,
                               rx: &mut Box<dyn TransportRx>,
                               qid: u64,
                               what: &str|
                 -> Result<()> {
                    tx.send(&Frame::ObsQuery { id: qid })?;
                    match rx.recv()? {
                        Some(Frame::ObsReport { id, json }) if id == qid => {
                            let r = Json::parse(&json)
                                .map_err(|e| anyhow::anyhow!("bad ObsReport: {e:?}"))?;
                            print_obs(what, &r);
                            Ok(())
                        }
                        other => anyhow::bail!("operator expected ObsReport, got {other:?}"),
                    }
                };

                std::thread::sleep(step);
                let (global, ticket) = ctl.add_lane(LaneSpec::new(fresh, lane_config(), qos()))?;
                let out = ticket.wait(ACK)?;
                print_topo(
                    &format!(
                        "added lane {global} -> partition {} slot {} (under traffic)",
                        out.global, out.local
                    ),
                    &ctl.snapshot(),
                );
                let (ok1, nl1, first1) = burst(&mut tx, &mut rx, global, BURST)?;
                ensure!(ok1 == BURST as u64 && nl1 == 0, "factory burst: {ok1} ok {nl1} nolane");
                println!("    burst of {BURST} served by factory weights (echo[0] = {first1})");
                observe(&mut tx, &mut rx, 9001, "add")?;

                std::thread::sleep(step);
                let pause = ctl.swap_model(global, SWAP_TAG)?.wait(ACK)?;
                print_topo(
                    &format!("hot-swapped lane {global} to tag {SWAP_TAG} (pause {pause:?})"),
                    &ctl.snapshot(),
                );
                let (ok2, nl2, first2) = burst(&mut tx, &mut rx, global, BURST)?;
                ensure!(ok2 == BURST as u64 && nl2 == 0, "swapped burst: {ok2} ok {nl2} nolane");
                println!(
                    "    burst of {BURST} served by NEW weights (echo[0] = {first2}, \
                     shifted by tag*SWAP_SCALE = {})",
                    SWAP_TAG as f32 * SWAP_SCALE
                );
                observe(&mut tx, &mut rx, 9002, "swap")?;

                std::thread::sleep(step);
                ctl.remove_lane(global)?.wait(ACK)?;
                print_topo(
                    &format!("removed lane {global} (drained, then excised)"),
                    &ctl.snapshot(),
                );
                let (ok3, nl3, _) = burst(&mut tx, &mut rx, global, 3)?;
                ensure!(ok3 == 0 && nl3 == 3, "dead lane: {ok3} ok {nl3} nolane");
                println!("    3 follow-up frames to lane {global}: all typed NoLane rejects");
                observe(&mut tx, &mut rx, 9003, "remove")?;

                tx.send(&Frame::Eos)?;
                Ok(format!(
                    "operator: add+swap+remove acked; {}+{} burst responses, 3 NoLane",
                    ok1, ok2
                ))
            })
        };

        // open-loop producers over the three construction-time lanes
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for shard in shards {
            let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr).expect("connect"));
            let (mut tx, mut rx) = t.split().expect("split");
            receivers.push(s.spawn(move || {
                let (mut ok, mut rejected) = (0u64, 0u64);
                loop {
                    match rx.recv() {
                        Ok(Some(Frame::Response { .. })) => ok += 1,
                        Ok(Some(Frame::Reject { .. })) => rejected += 1,
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return (ok, rejected),
                    }
                }
            }));
            senders.push(s.spawn(move || {
                let sent = shard.drive(horizon, |a| {
                    let _ = tx.send(&Frame::Request {
                        id: a.id,
                        lane: a.lane as u32,
                        model_idx: a.model_idx as u32,
                        shape: INPUT_SHAPE.to_vec(),
                        data: vec![0.5; 4],
                    });
                });
                let _ = tx.send(&Frame::Eos);
                sent
            }));
        }

        let sent: u64 = senders.into_iter().map(|t| t.join().unwrap()).sum();
        let op_report = op.join().unwrap();
        let conns = accept.join().unwrap();
        bridge.close();
        runner.join().unwrap().expect("elastic dispatch failed");
        for c in conns {
            c.shutdown();
        }
        let (mut ok, mut rejected) = (0u64, 0u64);
        for r in receivers {
            let (o, j) = r.join().unwrap();
            ok += o;
            rejected += j;
        }
        (sent, ok, rejected, op_report)
    });
    println!("{}", op_report?);

    let st = stats.read();
    println!(
        "\nopen loop done: {sent} sent -> {ok} responses + {rejected} rejects \
         ({} rounds, {} merged, {} admitted, {} ctrl ops, {} NoLane)",
        st.rounds, st.coalesced_rounds, st.admitted, st.ctrl_ops, st.no_lane
    );
    let gs = d.part(0).group_stats(0);
    println!(
        "coalesce group: {} merged rounds -> {} responses (kept merging through churn)",
        gs.rounds, gs.responses
    );
    print_topo("final topology", &ctl.snapshot());
    Ok(())
}
