"""AOT pipeline invariants. Full-manifest checks run only when
``artifacts/`` has been built (``make artifacts``); the lowering check
always runs on a tiny model."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, models, netfuse, weights
from compile.graphir import Graph
from compile.model import Interpreter, input_shape

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ARTIFACTS = os.path.exists(os.path.join(ART, "manifest.json"))


def test_lower_graph_produces_hlo_text():
    g = models.build("bert", layers=1, hidden=8, heads=2, seq=4, classes=2)
    hlo, interp, ishape, oshape = aot.lower_graph(g, 1, "xla")
    assert "HloModule" in hlo
    assert ishape == (1, 4, 8)
    assert oshape[-1] == 2
    assert len(interp.order) > 0


def test_lower_merged_graph():
    g = models.build("bert", layers=1, hidden=8, heads=2, seq=4, classes=2)
    mg = netfuse.merge(g, 2)
    hlo, interp, ishape, oshape = aot.lower_graph(mg, 1, "xla")
    assert ishape == (2, 1, 4, 8)
    assert oshape[0] == 2


def test_act_bytes_positive_and_scales():
    g = models.build("resnet")
    a1 = aot.act_bytes(g, 1)
    a4 = aot.act_bytes(g, 4)
    assert 0 < a1 < a4


def test_weight_bytes_matches_bank():
    g = models.build("resnext")
    bank = weights.init_bank(g, 0)
    total = sum(v.nbytes for v in bank.values())
    assert aot.weight_bytes(g) == total


def test_source_digest_stable():
    assert aot.source_digest() == aot.source_digest()


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="artifacts/ not built")
def test_manifest_structure():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == {"resnet", "resnext", "bert", "xlnet"}
    names = {a["name"] for a in man["artifacts"]}
    assert len(names) == len(man["artifacts"]), "duplicate artifact names"
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["hlo"])), a["hlo"]
        # param order in the manifest matches the interpreter's
        g = Graph.from_json(a["graph"])
        interp = Interpreter(g, "xla")
        assert [p["key"] for p in a["params"]] == interp.order, a["name"]
        assert tuple(a["input"]["shape"]) == input_shape(g, a["bs"])


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="artifacts/ not built")
def test_weight_banks_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        bank = weights.read_nft(os.path.join(ART, entry["weights"]))
        g = Graph.from_json(entry["graph"])
        want_per_instance = {f"{n.id}.{w}" for n in g.nodes for w in n.weights}
        for i in range(entry["instances"]):
            keys = {k.split("/", 1)[1] for k in bank if k.startswith(f"m{i}/")}
            assert keys == want_per_instance, f"{name} instance {i}"


@pytest.mark.skipif(not HAVE_ARTIFACTS, reason="artifacts/ not built")
def test_golden_vectors_satisfy_invariant():
    for name in ["resnet", "resnext", "bert", "xlnet"]:
        g = weights.read_nft(os.path.join(ART, "golden", f"{name}.nft"))
        for i in range(2):
            np.testing.assert_allclose(
                g["y_fused"][i], g[f"y{i}"], rtol=1e-4, atol=1e-5)
