"""Graph IR: JSON round-trip, validation, builder invariants."""

import json

import pytest

from compile import models
from compile.graphir import Graph, GraphBuilder, Node, MERGE_DIM, TRAINABLE


@pytest.mark.parametrize("name", ["resnet", "resnext", "bert", "xlnet"])
def test_json_roundtrip(name):
    g = models.build(name)
    g2 = Graph.loads(g.dumps())
    assert g2.to_json() == g.to_json()


def test_every_kind_has_merge_dim():
    for k in TRAINABLE:
        assert k in MERGE_DIM


def test_validate_rejects_duplicate_ids():
    n = Node("a", "relu", ["input"])
    g = Graph("g", (4,), [n, Node("a", "relu", ["input"])], "a")
    with pytest.raises(ValueError):
        g.validate()


def test_validate_rejects_forward_reference():
    g = Graph("g", (4,), [Node("a", "relu", ["b"]),
                          Node("b", "relu", ["input"])], "b")
    with pytest.raises(ValueError):
        g.validate()


def test_validate_rejects_unknown_kind():
    g = Graph("g", (4,), [Node("a", "warp_drive", ["input"])], "a")
    with pytest.raises(ValueError):
        g.validate()


def test_validate_rejects_missing_weights():
    g = Graph("g", (4,), [Node("a", "dense", ["input"], {"fin": 4,
                                                         "fout": 4})], "a")
    with pytest.raises(ValueError):
        g.validate()


def test_validate_rejects_weights_on_nontrainable():
    g = Graph("g", (4,), [Node("a", "relu", ["input"],
                               weights={"w": (4,)})], "a")
    with pytest.raises(ValueError):
        g.validate()


def test_validate_rejects_bad_output():
    g = Graph("g", (4,), [Node("a", "relu", ["input"])], "zzz")
    with pytest.raises(ValueError):
        g.validate()


def test_builder_produces_fresh_ids():
    b = GraphBuilder("g", (4,))
    a = b.dense("input", 4, 4)
    c = b.dense(a, 4, 4)
    assert a != c


def test_model_zoo_shapes():
    g = models.build("resnet")
    assert len(g.input_shape) == 3
    g = models.build("bert", layers=3)
    assert sum(1 for n in g.nodes if n.kind == "attention") == 3


def test_unmergeable_heads_flagged():
    for name in ["resnet", "resnext", "bert", "xlnet"]:
        g = models.build(name)
        heads = [n for n in g.nodes if not n.mergeable]
        assert len(heads) == 1 and heads[0].kind == "dense"
