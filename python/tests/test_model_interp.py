"""Interpreter-level behaviour: packing round-trips, refmt semantics,
input shapes, and error handling."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import models, netfuse, weights
from compile.graphir import Graph, GraphBuilder, Node
from compile.model import (Interpreter, input_shape, pack_inputs,
                           unpack_outputs, param_order)


def test_pack_unpack_batch_roundtrip():
    xs = [np.full((2, 3), float(i), np.float32) for i in range(4)]
    packed = pack_inputs(xs, "batch")
    assert packed.shape == (4, 2, 3)
    outs = unpack_outputs(np.asarray(packed), 4)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(o, xs[i])


def test_pack_channel_concatenates_nchw():
    xs = [np.full((2, 3, 4, 4), float(i), np.float32) for i in range(2)]
    packed = pack_inputs(xs, "channel")
    assert packed.shape == (2, 6, 4, 4)
    np.testing.assert_array_equal(np.asarray(packed)[:, :3], xs[0])
    np.testing.assert_array_equal(np.asarray(packed)[:, 3:], xs[1])


def test_pack_rejects_bad_layout():
    with pytest.raises(ValueError):
        pack_inputs([np.zeros((1, 2), np.float32)], "diagonal")


def test_input_shape_variants():
    g = models.build("resnet")
    assert input_shape(g, 2) == (2, 3, 16, 16)
    mg = netfuse.merge(g, 4)
    assert input_shape(mg, 2) == (2, 12, 16, 16)
    b = models.build("bert")
    mb = netfuse.merge(b, 4)
    assert input_shape(mb, 2) == (4, 2, 16, 32)


def test_refmt_roundtrip_is_identity():
    """channel->batch then batch->channel is the identity (the pair the
    elision pass may cancel)."""
    g = models.build("bert")
    mg = netfuse.merge(g, 3)
    interp = Interpreter(mg, "xla")
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 5, 3 * 7)).astype(np.float32))
    to_b = Node("r1", "refmt", ["input"], {"src": "channel", "dst": "batch"})
    to_c = Node("r2", "refmt", ["input"], {"src": "batch", "dst": "channel"})
    xb = interp._op_refmt(to_b, x)
    assert xb.shape == (3, 2, 5, 7)
    xc = interp._op_refmt(to_c, xb)
    np.testing.assert_array_equal(np.asarray(xc), np.asarray(x))


def test_refmt_rank4_nchw():
    g = models.build("resnet")
    mg = netfuse.merge(g, 2)
    interp = Interpreter(mg, "xla")
    x = jnp.asarray(np.arange(2 * 6 * 2 * 2, dtype=np.float32)
                    .reshape(2, 6, 2, 2))
    n = Node("r", "refmt", ["input"], {"src": "channel", "dst": "batch"})
    y = interp._op_refmt(n, x)
    assert y.shape == (2, 2, 3, 2, 2)
    np.testing.assert_array_equal(np.asarray(y[0]), np.asarray(x[:, :3]))
    np.testing.assert_array_equal(np.asarray(y[1]), np.asarray(x[:, 3:]))


def test_interpreter_rejects_wrong_param_count():
    g = models.build("bert")
    interp = Interpreter(g, "xla")
    x = jnp.zeros((1, *g.input_shape), jnp.float32)
    with pytest.raises(ValueError):
        interp(x)


def test_interpreter_rejects_bad_backend():
    with pytest.raises(ValueError):
        Interpreter(models.build("bert"), "tpu")


def test_param_order_is_topo_then_sorted():
    b = GraphBuilder("t", (4,))
    d = b.dense("input", 4, 4)
    l = b.layernorm(d, 4)
    g = b.build(l)
    order = param_order(g)
    assert order[0].endswith(".b") and order[1].endswith(".w")
    assert order[2].endswith(".beta") and order[3].endswith(".gamma")


def test_unknown_kind_raises():
    g = Graph("g", (4,), [Node("a", "relu", ["input"])], "a")
    g.nodes[0].kind = "mystery"
    interp = Interpreter.__new__(Interpreter)
    interp.g = g
    interp.backend = "xla"
    with pytest.raises(ValueError):
        interp._eval(g.nodes[0], [jnp.zeros((1, 4))], [])


def test_backbone_only_merge_heads_stay_separate():
    """§6: the task-specific heads are per-instance in the merged graph
    and use each instance's own weights."""
    g = models.build("resnet")
    m = 3
    mg = netfuse.merge(g, m)
    head = next(n for n in g.nodes if not n.mergeable)
    slices = [n for n in mg.nodes if n.id.startswith(f"{head.id}__slice")]
    heads = [n for n in mg.nodes if n.id.startswith(f"{head.id}__m")]
    stacks = [n for n in mg.nodes if n.id == f"{head.id}__stack"]
    assert len(slices) == m and len(heads) == m and len(stacks) == 1
    banks = weights.init_banks(g, m)
    mw = netfuse.merge_weights(g, mg, banks)
    for i in range(m):
        np.testing.assert_array_equal(
            mw[f"{head.id}__m{i}.w"], banks[i][f"{head.id}.w"])
