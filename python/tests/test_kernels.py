"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes/strides/groups; assert_allclose against ref.py.
This is the core numeric signal for the whole stack: the AOT'd HLO the
Rust runtime executes contains exactly these kernels.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import batch_matmul, grouped_conv, group_norm
from compile.kernels import ref

SET = dict(max_examples=25, deadline=None)


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# batch matmul
# ---------------------------------------------------------------------------

@settings(**SET)
@given(b=st.integers(1, 6), n=st.integers(1, 9), k=st.integers(1, 17),
       f=st.sampled_from([1, 2, 3, 5, 8, 16, 48, 128, 256]),
       seed=st.integers(0, 2**31))
def test_batch_matmul_matches_ref(b, n, k, f, seed):
    rng = np.random.default_rng(seed)
    x, w, bias = rnd(rng, b, n, k), rnd(rng, b, k, f), rnd(rng, b, f)
    got = np.asarray(batch_matmul(x, w, bias))
    want = np.asarray(ref.batch_matmul_ref(x, w, bias))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_batch_matmul_is_per_pair_local():
    """The input-weight locality property itself: pair i's output depends
    only on pair i's input and weights (paper §3)."""
    rng = np.random.default_rng(0)
    x, w, b = rnd(rng, 3, 4, 5), rnd(rng, 3, 5, 6), rnd(rng, 3, 6)
    base = np.asarray(batch_matmul(x, w, b))
    x2 = x.copy()
    x2[1] += 100.0
    pert = np.asarray(batch_matmul(x2, w, b))
    assert_allclose(pert[0], base[0], rtol=1e-6)
    assert_allclose(pert[2], base[2], rtol=1e-6)
    assert np.abs(pert[1] - base[1]).max() > 1.0


def test_batch_matmul_f_tiling_exact():
    # F not a power of two exercises the tile-selection fallback
    rng = np.random.default_rng(1)
    x, w, b = rnd(rng, 2, 3, 7), rnd(rng, 2, 7, 12), rnd(rng, 2, 12)
    assert_allclose(np.asarray(batch_matmul(x, w, b)),
                    np.asarray(ref.batch_matmul_ref(x, w, b)),
                    rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped conv
# ---------------------------------------------------------------------------

@settings(**SET)
@given(n=st.integers(1, 3), g=st.sampled_from([1, 2, 4, 8]),
       cg=st.integers(1, 6), co=st.integers(1, 6),
       k=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
       hw=st.integers(4, 10), seed=st.integers(0, 2**31))
def test_grouped_conv_matches_ref(n, g, cg, co, k, stride, hw, seed):
    rng = np.random.default_rng(seed)
    pad = k // 2
    x = rnd(rng, n, g * cg, hw, hw)
    w = rnd(rng, g * co, cg, k, k)
    b = rnd(rng, g * co)
    got = np.asarray(grouped_conv(x, w, b, stride=stride, padding=pad,
                                  groups=g))
    want = np.asarray(ref.grouped_conv_ref(x, w, b, stride=stride,
                                           padding=pad, groups=g))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_grouped_conv_group_isolation():
    """Appendix A property: perturbing group 0's input never changes
    group 1's output channels."""
    rng = np.random.default_rng(2)
    g, cg, co = 2, 3, 4
    x = rnd(rng, 2, g * cg, 8, 8)
    w = rnd(rng, g * co, cg, 3, 3)
    b = rnd(rng, g * co)
    base = np.asarray(grouped_conv(x, w, b, stride=1, padding=1, groups=g))
    x2 = x.copy()
    x2[:, :cg] += 50.0
    pert = np.asarray(grouped_conv(x2, w, b, stride=1, padding=1, groups=g))
    assert_allclose(pert[:, co:], base[:, co:], rtol=1e-5)
    assert np.abs(pert[:, :co] - base[:, :co]).max() > 1.0


def test_grouped_conv_equals_m_convs():
    """Appendix A, Eq. 6: GroupConv(concat x, concat w, M) == M convs."""
    rng = np.random.default_rng(3)
    m, c, co = 3, 4, 5
    xs = [rnd(rng, 2, c, 6, 6) for _ in range(m)]
    ws = [rnd(rng, co, c, 3, 3) for _ in range(m)]
    bs = [rnd(rng, co) for _ in range(m)]
    xcat = np.concatenate(xs, axis=1)
    wcat = np.concatenate(ws, axis=0)
    bcat = np.concatenate(bs, axis=0)
    fused = np.asarray(grouped_conv(xcat, wcat, bcat, stride=1, padding=1,
                                    groups=m))
    for i in range(m):
        want = np.asarray(ref.grouped_conv_ref(xs[i], ws[i], bs[i],
                                               stride=1, padding=1))
        assert_allclose(fused[:, i * co:(i + 1) * co], want,
                        rtol=1e-4, atol=1e-4)


def test_grouped_conv_1x1_stride1():
    rng = np.random.default_rng(4)
    x, w, b = rnd(rng, 1, 8, 5, 5), rnd(rng, 6, 4, 1, 1), rnd(rng, 6)
    got = np.asarray(grouped_conv(x, w, b, stride=1, padding=0, groups=2))
    want = np.asarray(ref.grouped_conv_ref(x, w, b, stride=1, padding=0,
                                           groups=2))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# group norm
# ---------------------------------------------------------------------------

@settings(**SET)
@given(n=st.integers(1, 16), g=st.sampled_from([1, 2, 4, 8]),
       cg=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_group_norm_matches_ref(n, g, cg, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, n, g * cg)
    gamma, beta = rnd(rng, g * cg), rnd(rng, g * cg)
    got = np.asarray(group_norm(x, gamma, beta, groups=g))
    want = np.asarray(ref.group_norm_ref(x, gamma, beta, groups=g))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_group_norm_equals_m_layernorms():
    """Paper §3.1: group norm with M groups == M merged layer norms."""
    rng = np.random.default_rng(5)
    m, h, n = 4, 8, 6
    xs = [rnd(rng, n, h) for _ in range(m)]
    gs = [rnd(rng, h) for _ in range(m)]
    bs = [rnd(rng, h) for _ in range(m)]
    xcat = np.concatenate(xs, axis=1)
    fused = np.asarray(group_norm(
        xcat, np.concatenate(gs), np.concatenate(bs), groups=m))
    for i in range(m):
        want = np.asarray(ref.group_norm_ref(xs[i], gs[i], bs[i], groups=1))
        assert_allclose(fused[:, i * h:(i + 1) * h], want,
                        rtol=1e-4, atol=1e-4)


def test_group_norm_output_stats():
    rng = np.random.default_rng(6)
    x = rnd(rng, 4, 32) * 3 + 5
    y = np.asarray(group_norm(x, np.ones(32, np.float32),
                              np.zeros(32, np.float32), groups=2))
    yg = y.reshape(4, 2, 16)
    assert_allclose(yg.mean(axis=-1), 0.0, atol=1e-4)
    assert_allclose(yg.std(axis=-1), 1.0, atol=1e-2)
