"""Algorithm 1 correctness: structure of merged graphs + end-to-end
numerical equivalence (merged output == per-instance outputs) for every
model in the zoo — the paper's central claim ("NETFUSE does not alter the
computation results in any way", §5)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import models, netfuse, weights
from compile.graphir import BATCH, CHANNEL, Graph, GraphBuilder
from compile.model import (Interpreter, input_shape, pack_inputs,
                           unpack_outputs)

MODELS = ["resnet", "resnext", "bert", "xlnet"]


def run_graph(g, bank_list_or_bank, x):
    interp = Interpreter(g, "xla")
    bank = bank_list_or_bank
    params = [jnp.asarray(bank[k]) for k in interp.order]
    return np.asarray(interp(jnp.asarray(x), *params))


# ---------------------------------------------------------------------------
# structural properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MODELS)
@pytest.mark.parametrize("m", [1, 2, 4])
def test_merge_is_valid_graph(name, m):
    g = models.build(name)
    mg = netfuse.merge(g, m)
    mg.validate()
    assert mg.merged_m == m


@pytest.mark.parametrize("name", MODELS)
def test_merge_replaces_ops_with_counterparts(name):
    g = models.build(name)
    mg = netfuse.merge(g, 4)
    kinds = {n.kind for n in mg.nodes}
    assert "layernorm" not in kinds          # LN -> GN always
    for n in g.nodes:
        if n.kind == "conv2d":
            mn = mg.node(n.id)
            # groups multiply: M x G (paper §3.1)
            assert mn.attrs["groups"] == 4 * n.attrs["groups"]
            assert mn.attrs["cout"] == 4 * n.attrs["cout"]
        if n.kind == "layernorm":
            mn = mg.node(n.id)
            assert mn.kind == "groupnorm" and mn.attrs["groups"] == 4


@pytest.mark.parametrize("name", MODELS)
def test_merge_preserves_topology_modulo_fixups(name):
    """Every original node id survives; only refmt/slice/stack are added."""
    g = models.build(name)
    mg = netfuse.merge(g, 3)
    orig = {n.id for n in g.nodes}
    added = {n.id for n in mg.nodes} - orig
    for nid in added:
        assert (nid.startswith("refmt_") or "__slice" in nid
                or "__m" in nid or nid.endswith("__stack")), nid
    # mergeable originals survive under their own id
    for n in g.nodes:
        if n.mergeable:
            assert any(x.id == n.id for x in mg.nodes)


def test_refmt_inserted_on_dim_conflict():
    """Paper Figure 4: bmm (Batch) feeding group norm (Channel) needs a
    reshape between them."""
    b = GraphBuilder("ffnn", (8,))
    x = b.dense("input", 8, 8)
    x = b.layernorm(x, 8)
    g = b.build(x)
    mg = netfuse.merge(g, 2)
    kinds = [n.kind for n in mg.nodes]
    assert "refmt" in kinds
    # the refmt sits between the dense and the groupnorm
    gn = next(n for n in mg.nodes if n.kind == "groupnorm")
    ref = mg.node(gn.inputs[0])
    assert ref.kind == "refmt"
    assert ref.attrs == {"src": "batch", "dst": "channel"}


def test_no_refmt_when_dims_agree():
    """conv -> bn -> relu chain is all-Channel: zero fix-ups."""
    b = GraphBuilder("cnn", (3, 8, 8))
    x = b.conv2d("input", 3, 4, k=3)
    x = b.batchnorm(x, 4)
    x = b.relu(x)
    g = b.build(x)
    mg = netfuse.merge(g, 4)
    assert all(n.kind != "refmt" for n in mg.nodes)


def test_refmt_shared_across_diamond():
    """A fork consuming the same conversion gets one refmt, not two."""
    b = GraphBuilder("fork", (8,))
    x = b.dense("input", 8, 8)
    l1 = b.layernorm(x, 8)
    l2 = b.layernorm(x, 8)
    # recombine in channel domain
    y = b.residual(l1, l2)
    g = b.build(y)
    mg = netfuse.merge(g, 2)
    refmts = [n for n in mg.nodes if n.kind == "refmt"]
    assert len(refmts) == 1


def test_merge_m1_identity_semantics():
    g = models.build("bert")
    mg = netfuse.merge(g, 1)
    bank = weights.init_bank(g, 3)
    mw = netfuse.merge_weights(g, mg, [bank])
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, *g.input_shape)).astype(np.float32)
    y1 = run_graph(g, bank, x)
    ym = run_graph(mg, mw, pack_inputs([x], "batch"))
    np.testing.assert_allclose(ym[0], y1, rtol=1e-5, atol=1e-6)


def test_merge_rejects_double_merge():
    g = models.build("bert")
    mg = netfuse.merge(g, 2)
    with pytest.raises(netfuse.MergeError):
        netfuse.merge(mg, 2)


def test_merge_rejects_bad_m():
    with pytest.raises(netfuse.MergeError):
        netfuse.merge(models.build("bert"), 0)


# ---------------------------------------------------------------------------
# end-to-end numerical equivalence (the paper's core claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MODELS)
@pytest.mark.parametrize("m,bs", [(2, 1), (4, 2)])
def test_fused_equals_individuals(name, m, bs):
    g = models.build(name)
    mg = netfuse.merge(g, m)
    banks = weights.init_banks(g, m)
    mw = netfuse.merge_weights(g, mg, banks)
    rng = np.random.default_rng(99)
    xs = [rng.normal(size=(bs, *g.input_shape)).astype(np.float32)
          for _ in range(m)]
    singles = [run_graph(g, banks[i], xs[i]) for i in range(m)]
    ym = run_graph(mg, mw, pack_inputs(xs, mg.layout))
    outs = unpack_outputs(ym, m)
    for i in range(m):
        np.testing.assert_allclose(outs[i], singles[i],
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["resnet", "bert"])
def test_fused_equals_individuals_pallas(name):
    """Same equivalence through the Pallas kernel path (L1)."""
    m, bs = 2, 1
    g = models.build(name)
    mg = netfuse.merge(g, m)
    banks = weights.init_banks(g, m)
    mw = netfuse.merge_weights(g, mg, banks)
    rng = np.random.default_rng(7)
    xs = [rng.normal(size=(bs, *g.input_shape)).astype(np.float32)
          for _ in range(m)]
    single = Interpreter(g, "pallas")
    merged = Interpreter(mg, "pallas")
    singles = [np.asarray(single(jnp.asarray(xs[i]),
                                 *[jnp.asarray(banks[i][k])
                                   for k in single.order]))
               for i in range(m)]
    ym = np.asarray(merged(pack_inputs(xs, mg.layout),
                           *[jnp.asarray(mw[k]) for k in merged.order]))
    for i, got in enumerate(unpack_outputs(ym, m)):
        np.testing.assert_allclose(got, singles[i], rtol=1e-4, atol=1e-4)


def test_weight_merge_shapes_checked():
    g = models.build("bert")
    mg = netfuse.merge(g, 2)
    banks = weights.init_banks(g, 2)
    banks[1] = {k: v[..., :1] for k, v in banks[1].items()}  # corrupt
    with pytest.raises(Exception):
        netfuse.merge_weights(g, mg, banks)


def test_distinct_weights_give_distinct_outputs():
    """Sanity: the M instances really are different models."""
    g = models.build("resnet")
    banks = weights.init_banks(g, 2)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(1, *g.input_shape)).astype(np.float32)
    y0 = run_graph(g, banks[0], x)
    y1 = run_graph(g, banks[1], x)
    assert np.abs(y0 - y1).max() > 1e-3
