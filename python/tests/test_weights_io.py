"""Weight banks + .nft container round-trip (shared format with rust)."""

import numpy as np
import pytest

from compile import models, weights


def test_nft_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b/nested.name": rng.normal(size=(2, 3, 4, 5)).astype(np.float32),
        "scalar": np.float32(3.25).reshape(()),
        "vec": rng.normal(size=(7,)).astype(np.float32),
    }
    p = tmp_path / "t.nft"
    weights.write_nft(str(p), tensors)
    back = weights.read_nft(str(p))
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


def test_nft_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.nft"
    p.write_bytes(b"XXXX\x00\x00\x00\x00")
    with pytest.raises(ValueError):
        weights.read_nft(str(p))


def test_banks_are_deterministic():
    g = models.build("bert")
    a = weights.init_bank(g, 7)
    b = weights.init_bank(g, 7)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_banks_differ_across_instances():
    g = models.build("bert")
    banks = weights.init_banks(g, 2)
    diffs = [np.abs(banks[0][k] - banks[1][k]).max() for k in banks[0]]
    assert max(diffs) > 0.01


def test_bank_covers_all_weights():
    g = models.build("resnext")
    bank = weights.init_bank(g, 0)
    want = {f"{n.id}.{w}" for n in g.nodes for w in n.weights}
    assert set(bank) == want
    for n in g.nodes:
        for wname, shape in n.weights.items():
            assert bank[f"{n.id}.{wname}"].shape == tuple(shape)


def test_var_is_positive():
    g = models.build("resnet")
    bank = weights.init_bank(g, 0)
    for k, v in bank.items():
        if k.endswith(".var"):
            assert (v > 0).all()
