"""Per-instance weight banks + the ``.nft`` tensor container format.

Each model instance gets its own deterministic, *distinct* random weights
(the paper's fine-tuned instances differ only in values; NETFUSE never
inspects values, only shapes — DESIGN.md §4). The ``.nft`` container is
the interchange format with the Rust coordinator's weight store
(``rust/src/tensor/io.rs`` implements the same layout):

    magic  b"NFT1"
    u32    tensor count (little endian)
    per tensor:
        u16  name length, then name bytes (utf-8)
        u8   dtype (0 = f32)
        u8   ndim
        u32  dims[ndim]
        f32  data[prod(dims)]  (little endian)
"""

from __future__ import annotations

import struct

import numpy as np

from .graphir import Graph

MAGIC = b"NFT1"


def init_bank(g: Graph, seed: int) -> dict:
    """Weights for one model instance: ``{"node.weight": ndarray}``."""
    rng = np.random.default_rng(seed)
    bank = {}
    for n in g.nodes:
        for wname, shape in n.weights.items():
            key = f"{n.id}.{wname}"
            if wname in ("gamma",):
                arr = rng.uniform(0.7, 1.3, size=shape)
            elif wname in ("beta", "b", "mean", "u", "v"):
                arr = rng.normal(0.0, 0.05, size=shape)
            elif wname == "var":
                arr = rng.uniform(0.5, 1.5, size=shape)
            else:
                fan_in = int(np.prod(shape[:-1])) or 1
                arr = rng.normal(0.0, 1.0 / np.sqrt(fan_in), size=shape)
            bank[key] = arr.astype(np.float32)
    return bank


def init_banks(g: Graph, m: int, base_seed: int = 7) -> list[dict]:
    """M distinct instances (distinct seeds => distinct fine-tunings)."""
    return [init_bank(g, base_seed + 1000 * i) for i in range(m)]


# ---------------------------------------------------------------------------
# .nft io
# ---------------------------------------------------------------------------

def write_nft(path: str, tensors: dict) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_nft(path: str) -> dict:
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {data[:4]!r}")
    off = 4
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    out = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode()
        off += nlen
        dtype, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        if dtype != 0:
            raise ValueError(f"{path}: unsupported dtype {dtype}")
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype="<f4", count=n, offset=off)
        off += 4 * n
        out[name] = arr.reshape(dims).copy()
    return out
