"""NETFUSE Algorithm 1: merging M same-architecture DNNs into one graph.

Faithful implementation of the paper's Algorithm 1 (§3.2): a BFS traversal
over the common subgraph that

  1. replaces every op with its *input-weight local* counterpart
     (matmul -> batch matmul, conv -> grouped conv with M x G groups,
     layer norm -> group norm, batch norm -> wider batch norm,
     non-trainable ops -> themselves),
  2. assigns each merged op its merge dimension d_i in
     {Batch, Channel, DontCare} (DontCare inherits the most frequent
     parent dimension — "follow the majority if there is a dissensus"),
  3. inserts reshape-and-transpose fix-up ops ("refmt") on every edge
     whose endpoint dimensions disagree, and
  4. leaves ``mergeable=False`` nodes (task-specific heads, §6) as M
     per-instance ops bracketed by slice/stack.

Layout conventions of the merged graph (see DESIGN.md):
  * Channel packing: instances concatenated on the channel axis —
    NCHW axis 1 for CNN tensors, the last axis for transformer tensors.
    CNN graph input is channel-packed: [bs, M*C, H, W].
  * Batch packing: instances stacked on a new leading axis —
    [M, bs, ...]. Transformer graph input is batch-packed.

The same algorithm is re-implemented in Rust (``rust/src/fuse``) as the
serving-side planner; integration tests assert both produce isomorphic
merged graphs from identical JSON inputs.
"""

from __future__ import annotations

from collections import deque

from .graphir import (BATCH, CHANNEL, DONTCARE, MERGE_DIM, Graph, Node)


class MergeError(ValueError):
    pass


def _input_dim(g: Graph) -> str:
    """Packing of the merged graph input: CNNs concat on channel, sequence
    models stack on batch (their first trainable ops demand it)."""
    return CHANNEL if len(g.input_shape) == 3 else BATCH


def merge_node(n: Node, m: int) -> tuple[Node, str]:
    """Merge(op_i, {w_ij}) from Algorithm 1: one op's merged counterpart
    plus its required concat dimension."""
    k, a, w = n.kind, dict(n.attrs), dict(n.weights)
    if k == "conv2d":
        # conv -> grouped conv: M x G groups (paper §3.1, Appendix A)
        a["cin"] *= m
        a["cout"] *= m
        a["groups"] *= m
        w["w"] = (a["cout"], n.attrs["cin"] // n.attrs["groups"],
                  a["k"], a["k"])
        w["b"] = (a["cout"],)
        return Node(n.id, "conv2d", list(n.inputs), a, w), CHANNEL
    if k == "dense":
        # matmul -> batch matmul: weights stacked on a new leading axis
        a["merged_m"] = m
        w = {"w": (m, a["fin"], a["fout"]), "b": (m, a["fout"])}
        return Node(n.id, "dense", list(n.inputs), a, w), BATCH
    if k == "layernorm":
        # layer norm -> group norm with M groups
        dim = a.pop("dim")
        ga = {"c": dim * m, "groups": m}
        w = {"gamma": (dim * m,), "beta": (dim * m,)}
        return Node(n.id, "groupnorm", list(n.inputs), ga, w), CHANNEL
    if k == "groupnorm":
        a["c"] *= m
        a["groups"] *= m
        w = {"gamma": (a["c"],), "beta": (a["c"],)}
        return Node(n.id, "groupnorm", list(n.inputs), a, w), CHANNEL
    if k == "batchnorm":
        # per-channel computation: concat weights, no type change
        a["c"] *= m
        w = {name: (a["c"],) for name in w}
        return Node(n.id, "batchnorm", list(n.inputs), a, w), CHANNEL
    if k in ("attention", "xl_attention"):
        # composition of matmuls -> composition of batch matmuls
        a["merged_m"] = m
        w = {name: (m, *shape) for name, shape in w.items()}
        return Node(n.id, k, list(n.inputs), a, w), BATCH
    if k in MERGE_DIM and k not in ("refmt",):
        # non-trainable: merged seamlessly, no weights (paper §3.1)
        return Node(n.id, k, list(n.inputs), a, {}), DONTCARE
    raise MergeError(f"cannot merge op kind {k!r}")


def _refmt(counter: list[int], src: str, dst: str, parent: str) -> Node:
    counter[0] += 1
    return Node(
        id=f"refmt_{counter[0]}",
        kind="refmt",
        inputs=[parent],
        attrs={"src": src.lower(), "dst": dst.lower()},
    )


def merge(g: Graph, m: int) -> Graph:
    """Algorithm 1. Returns the merged graph for M instances of ``g``."""
    if m < 1:
        raise MergeError("m must be >= 1")
    g.validate()
    if g.merged_m != 1:
        raise MergeError("graph is already merged")

    in_dim = _input_dim(g)
    merged: list[Node] = []
    # merge dimension assigned to each produced node id ("input" included)
    dim_of: dict[str, str] = {"input": in_dim}
    # maps original node id -> id of the node carrying its merged output
    out_id: dict[str, str] = {"input": "input"}
    refmt_counter = [0]
    # cache: (parent_out_id, dst_dim) -> refmt node id, so diamonds (e.g.
    # residual forks) share a single fix-up op instead of duplicating it
    refmt_cache: dict[tuple[str, str], str] = {}

    visited: set[str] = set()
    indeg = {n.id: 0 for n in g.nodes}
    for n in g.nodes:
        for s in n.inputs:
            if s != "input":
                indeg[n.id] += 1
    q = deque(n for n in g.nodes if indeg[n.id] == 0)

    def connect(parent: str, want: str) -> str:
        """Return an id producing ``parent``'s value in packing ``want``,
        inserting a reshape-and-transpose op if packings disagree."""
        have = dim_of[out_id[parent]]
        if want == DONTCARE or have == want:
            return out_id[parent]
        key = (out_id[parent], want)
        if key not in refmt_cache:
            r = _refmt(refmt_counter, have, want, out_id[parent])
            merged.append(r)
            dim_of[r.id] = want
            refmt_cache[key] = r.id
        return refmt_cache[key]

    while q:
        op = q.popleft()
        if op.id in visited:
            continue
        visited.add(op.id)

        parent_dims = [dim_of[out_id[s]] for s in op.inputs]

        if not op.mergeable:
            # §6: task-specific layer kept per-instance. The merged graph
            # slices instance i's activations, applies instance i's
            # original op, and stacks the M results on a leading axis.
            if op.kind != "dense":
                raise MergeError(
                    f"unmergeable op {op.id!r} of kind {op.kind!r}: only "
                    "dense heads are supported per-instance")
            src = connect(op.inputs[0], BATCH)
            parts = []
            for i in range(m):
                sl = Node(f"{op.id}__slice{i}", "slice_m", [src],
                          {"index": i})
                merged.append(sl)
                dim_of[sl.id] = BATCH
                di = Node(f"{op.id}__m{i}", "dense", [sl.id],
                          {**op.attrs, "merged_m": 1},
                          dict(op.weights), mergeable=False)
                merged.append(di)
                dim_of[di.id] = BATCH
                parts.append(di.id)
            st = Node(f"{op.id}__stack", "stack_m", parts, {})
            merged.append(st)
            dim_of[st.id] = BATCH
            out_id[op.id] = st.id
        else:
            mi, di = merge_node(op, m)
            if di == DONTCARE:
                # lines 23-27: follow the majority of the parents
                # (ties resolve to Batch, deterministically — the Rust
                # planner in rust/src/fuse uses the same rule)
                n_b = parent_dims.count(BATCH)
                n_c = parent_dims.count(CHANNEL)
                if n_b == 0 and n_c == 0:
                    di = in_dim
                else:
                    di = CHANNEL if n_c > n_b else BATCH
            # lines 29-36: rewire through fix-up ops where dims differ
            mi.inputs = [connect(s, di) for s in op.inputs]
            merged.append(mi)
            dim_of[mi.id] = di
            out_id[op.id] = mi.id

        for child in g.consumers(op.id):
            indeg[child.id] -= 1
            if indeg[child.id] == 0:
                q.append(child)

    if len(visited) != len(g.nodes):
        raise MergeError("graph has a cycle or unreachable nodes")

    out = Graph(
        name=f"{g.name}_x{m}",
        input_shape=g.input_shape,
        nodes=merged,
        output=out_id[g.output],
        merged_m=m,
        layout="channel" if in_dim == CHANNEL else "batch",
    )
    out.validate()
    return out


# ---------------------------------------------------------------------------
# Weight merging
# ---------------------------------------------------------------------------

def merge_weights(g: Graph, merged: Graph, banks: list[dict]):
    """Build the merged graph's weight arrays from M per-instance banks.

    ``banks[i]`` maps ``"{node}.{weight}"`` to instance i's array.
    Returns the same mapping for the merged graph. Concat on axis 0 for
    Channel-merged ops (grouped conv / norms), stack on a new leading axis
    for Batch-merged ops (batch matmul / attention); per-instance heads
    take their own instance's array unchanged.
    """
    import numpy as np

    m = merged.merged_m
    if len(banks) != m:
        raise MergeError(f"expected {m} weight banks, got {len(banks)}")
    out = {}
    for node in merged.nodes:
        if not node.weights:
            continue
        if node.id.rpartition("__m")[2].isdigit() and "__m" in node.id:
            # per-instance head: {orig}__m{i}
            orig, _, idx = node.id.rpartition("__m")
            bank = banks[int(idx)]
            for wname in node.weights:
                out[f"{node.id}.{wname}"] = bank[f"{orig}.{wname}"]
            continue
        for wname, shape in node.weights.items():
            # merged layernorm became groupnorm but weight names match
            parts = [banks[i][f"{node.id}.{wname}"] for i in range(m)]
            if len(shape) > len(parts[0].shape):
                arr = np.stack(parts, axis=0)
            else:
                arr = np.concatenate(parts, axis=0) if m > 1 else parts[0]
            if tuple(arr.shape) != tuple(shape):
                raise MergeError(
                    f"merged weight {node.id}.{wname}: got {arr.shape}, "
                    f"expected {tuple(shape)}")
            out[f"{node.id}.{wname}"] = arr
    return out
