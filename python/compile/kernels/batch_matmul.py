"""Pallas batch-matmul kernel — the merged fully-connected hot path.

Merging M dense layers turns M (N,K)x(K,F) GEMMs into one batched GEMM
with a leading pair axis (paper §3.1, "Matrix multiplication"). The grid
iterates over (pair, F-tile); each grid step keeps one pair's K panel
resident in VMEM and contracts on the MXU.

TPU mapping (DESIGN.md §6): the B axis is embarrassingly parallel (zero
cross-pair traffic — that is the *input-weight locality* the paper needs),
the (N, K, F) tile is chosen so x-tile + w-tile + out-tile fit VMEM, and
the dot is MXU-shaped (pad N/K/F up to multiples of 128 at real scale).
Runs under interpret=True here: CPU PJRT cannot execute Mosaic calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref):
    # one (pair, F-tile) step: [1,N,K] @ [1,K,Ft] + [1,Ft]
    x = x_ref[0]
    w = w_ref[0]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[0] = acc + b_ref[0][None, :]


def _pick_ftile(f: int) -> int:
    # largest power-of-two tile <= 128 dividing F; keeps the MXU busy at
    # real scale without wasting VMEM on padding at mini scale.
    t = 1
    while t * 2 <= min(f, 128) and f % (t * 2) == 0:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def batch_matmul(x, w, b, interpret: bool = True):
    """x: [B, N, K], w: [B, K, F], b: [B, F] -> [B, N, F]."""
    bsz, n, k = x.shape
    _, _, f = w.shape
    ft = _pick_ftile(f)
    grid = (bsz, f // ft)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k, ft), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, ft), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, n, ft), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n, f), x.dtype),
        interpret=interpret,
    )(x, w, b)
