"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest/hypothesis sweep shapes and
assert the Pallas implementations (interpret mode) match to float32
tolerance. They are also the "xla" kernel backend used by the fast figure
artifacts (DESIGN.md): plain lax ops that XLA CPU fuses natively.
"""

import jax
import jax.numpy as jnp


def batch_matmul_ref(x, w, b=None):
    """x: [B, N, K], w: [B, K, F], b: [B, F] -> [B, N, F]."""
    y = jnp.einsum("bnk,bkf->bnf", x, w)
    if b is not None:
        y = y + b[:, None, :]
    return y


def grouped_conv_ref(x, w, b=None, stride=1, padding=0, groups=1):
    """NCHW grouped convolution.

    x: [N, G*Cg, H, W], w: [G*Co, Cg, k, k] -> [N, G*Co, Ho, Wo].
    """
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )
    if b is not None:
        y = y + b[None, :, None, None]
    return y


def group_norm_ref(x, gamma, beta, groups, eps=1e-5):
    """Row-wise group normalization on the last axis.

    x: [N, G*Cg]; each (row, group) chunk of Cg channels is normalized
    independently then affine-transformed. With G = M this is exactly M
    merged layer norms (paper Sec 3.1).
    """
    n, c = x.shape
    cg = c // groups
    xg = x.reshape(n, groups, cg)
    mu = xg.mean(axis=-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xg - mu) / jnp.sqrt(var + eps)
    return y.reshape(n, c) * gamma[None, :] + beta[None, :]
