"""Pallas grouped-convolution kernel — the merged conv hot path.

Merging M convolutions of G groups each yields one grouped convolution of
M*G groups (paper §3.1 + Appendix A). The grid iterates over groups: each
grid step loads exactly one group's input slab and filter block into VMEM
and never touches another group's data — the TPU expression of the
paper's "isolated input-weight pairs".

The conv itself is computed as shift-and-matmul: for each of the k*k
filter taps we take the strided window of the (pre-padded) input and
contract [Cg] x [Co, Cg] on the MXU, accumulating in f32. This avoids
im2col's VMEM blow-up and keeps every FLOP on the systolic array; the
k*k loop is unrolled at trace time (k is 1 or 3 everywhere in the model
zoo).

Runs under interpret=True (CPU PJRT cannot run Mosaic custom-calls);
real-TPU VMEM/MXU estimates are in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(k: int, stride: int, ho: int, wo: int):
    def kernel(x_ref, w_ref, b_ref, o_ref):
        x = x_ref[...]          # [N, Cg, Hp, Wp]   one group's inputs
        w = w_ref[...]          # [Co, Cg, k, k]    one group's filters
        acc = jnp.zeros(o_ref.shape, jnp.float32)
        for ki in range(k):
            for kj in range(k):
                # strided window aligned with output pixels
                win = jax.lax.slice(
                    x, (0, 0, ki, kj),
                    (x.shape[0], x.shape[1],
                     ki + (ho - 1) * stride + 1, kj + (wo - 1) * stride + 1),
                    (1, 1, stride, stride))      # [N, Cg, Ho, Wo]
                acc = acc + jnp.einsum(
                    "nchw,oc->nohw", win, w[:, :, ki, kj],
                    preferred_element_type=jnp.float32)
        o_ref[...] = acc + b_ref[...][None, :, None, None]
    return kernel


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "groups", "interpret"))
def grouped_conv(x, w, b, stride=1, padding=0, groups=1,
                 interpret: bool = True):
    """NCHW grouped conv. x: [N, G*Cg, H, W], w: [G*Co, Cg, k, k]."""
    n, c, h, wd = x.shape
    co_total, cg, k, _ = w.shape
    assert c == groups * cg, (c, groups, cg)
    co = co_total // groups
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp, wp = h + 2 * padding, wd + 2 * padding
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    kern = _make_kernel(k, stride, ho, wo)
    return pl.pallas_call(
        kern,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((n, cg, hp, wp), lambda g: (0, g, 0, 0)),
            pl.BlockSpec((co, cg, k, k), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((co,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((n, co, ho, wo), lambda g: (0, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, co_total, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, w, b)
