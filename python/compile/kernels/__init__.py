"""Layer-1 Pallas kernels (interpret mode) + pure-jnp oracles."""

from .batch_matmul import batch_matmul
from .grouped_conv import grouped_conv
from .group_norm import group_norm
from . import ref
