"""Pallas group-norm kernel — merged layer normalization.

M layer norms merge into one group norm with M groups (paper §3.1,
"Layer normalization"): the channel axis carries M concatenated hidden
vectors and each group is normalized independently. Grid iterates over
groups; one grid step does the mean/var reduction *and* the affine in a
single VMEM pass (the CUDA implementation needs two kernel launches).

Bandwidth-bound: arithmetic intensity ~ O(1) flops/byte, so the win on
real hardware is purely the single fused pass + one launch for all M
groups. interpret=True for CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]                       # [N, Cg] one group, all rows
    mu = jnp.mean(x, axis=1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=1, keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = xn * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("groups", "interpret"))
def group_norm(x, gamma, beta, groups, eps=1e-5, interpret: bool = True):
    """x: [N, G*Cg] row-wise group norm (see kernels/ref.py)."""
    n, c = x.shape
    cg = c // groups
    kern = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((n, cg), lambda g: (0, g)),
            pl.BlockSpec((cg,), lambda g: (g,)),
            pl.BlockSpec((cg,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((n, cg), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)
