"""Layer-2: graph-IR -> JAX interpreter.

Turns a (single or merged) :class:`graphir.Graph` into a JAX function
``fn(x, *params) -> y`` suitable for ``jax.jit(...).lower(...)``. The
conv / matmul / norm hot-spots dispatch to the Layer-1 Pallas kernels
(``backend="pallas"``) or to the pure-jnp oracles (``backend="xla"``,
used by the fast figure artifacts — see DESIGN.md §3).

Tensor conventions
------------------
single graphs      CNN: [bs, C, H, W] (NCHW);  seq: [bs, S, H]
channel packing    CNN: [bs, M*C, H, W];        seq: [bs, S, M*H]
batch packing      [M, bs, ...] (new leading instance axis)

``refmt`` nodes translate between packings (rank-4/5 tensors are NCHW-ish
with channel axis 1; rank-2/3 tensors are channel-last). ``slice_m`` /
``dense(mergeable=False)`` / ``stack_m`` implement the per-instance heads
the merge leaves untouched (paper §6).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .graphir import Graph, Node
from .kernels import batch_matmul, grouped_conv, group_norm
from .kernels import ref

EPS = 1e-5


def param_order(g: Graph) -> list[str]:
    """Deterministic parameter ordering shared with the Rust runtime:
    topological node order, then sorted weight names within a node."""
    out = []
    for n in g.nodes:
        for wname in sorted(n.weights):
            out.append(f"{n.id}.{wname}")
    return out


# ---------------------------------------------------------------------------
# packing helpers (shared by tests and aot)
# ---------------------------------------------------------------------------

def pack_inputs(xs, layout: str):
    """Stack M per-instance inputs into the merged graph's input tensor."""
    xs = [jnp.asarray(x) for x in xs]
    if layout == "channel":        # CNN: concat on channel axis (NCHW)
        return jnp.concatenate(xs, axis=1)
    if layout == "batch":          # seq: new leading instance axis
        return jnp.stack(xs, axis=0)
    raise ValueError(f"bad layout {layout!r}")


def unpack_outputs(y, m: int, layout_out: str = "batch"):
    """Split the merged output back into M per-instance outputs."""
    if layout_out == "batch":
        return [y[i] for i in range(m)]
    c = y.shape[1] // m
    return [y[:, i * c:(i + 1) * c] for i in range(m)]


# ---------------------------------------------------------------------------
# interpreter
# ---------------------------------------------------------------------------

class Interpreter:
    def __init__(self, g: Graph, backend: str = "xla"):
        if backend not in ("xla", "pallas"):
            raise ValueError(f"bad backend {backend!r}")
        g.validate()
        self.g = g
        self.backend = backend
        self.order = param_order(g)

    # -- primitive dispatch ---------------------------------------------------

    def _bmm(self, x3, w3, b2):
        if self.backend == "pallas":
            return batch_matmul(x3, w3, b2)
        return ref.batch_matmul_ref(x3, w3, b2)

    def _dense2d(self, x2, w, b):
        return self._bmm(x2[None], w[None], b[None])[0]

    def _conv(self, x, w, b, stride, padding, groups):
        if self.backend == "pallas":
            return grouped_conv(x, w, b, stride=stride, padding=padding,
                                groups=groups)
        return ref.grouped_conv_ref(x, w, b, stride=stride, padding=padding,
                                    groups=groups)

    def _gn_rows(self, x2, gamma, beta, groups):
        if self.backend == "pallas":
            return group_norm(x2, gamma, beta, groups)
        return ref.group_norm_ref(x2, gamma, beta, groups)

    # -- op implementations -----------------------------------------------------

    def _mm_any(self, x, w, b):
        """Matmul on the last axis; ``w`` rank-3 means merged (bmm over the
        leading instance axis), rank-2 means single."""
        if w.ndim == 3:
            lead, mid = x.shape[0], x.shape[1:-1]
            x3 = x.reshape(lead, -1, x.shape[-1])
            y = self._bmm(x3, w, b)
            return y.reshape(lead, *mid, w.shape[-1])
        mid = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = self._dense2d(x2, w, b)
        return y.reshape(*mid, w.shape[-1])

    def _proj(self, x, w):
        """Bias-free hidden projection (attention q/k/v/o)."""
        zeros = jnp.zeros(
            (w.shape[0], w.shape[-1]) if w.ndim == 3 else (w.shape[-1],),
            x.dtype)
        return self._mm_any(x, w, zeros)

    def _op_layernorm(self, n: Node, x, gamma, beta):
        mid = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = self._gn_rows(x2, gamma, beta, groups=1)
        return y.reshape(*mid, x.shape[-1])

    def _op_groupnorm(self, n: Node, x, gamma, beta):
        groups = n.attrs["groups"]
        mid = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = self._gn_rows(x2, gamma, beta, groups=groups)
        return y.reshape(*mid, x.shape[-1])

    def _op_batchnorm(self, n: Node, x, gamma, beta, mean, var):
        # inference-mode BN over NCHW channel axis 1
        sh = (1, -1, 1, 1)
        inv = jax.lax.rsqrt(var + EPS)
        return (x - mean.reshape(sh)) * (inv * gamma).reshape(sh) \
            + beta.reshape(sh)

    def _op_attention(self, n: Node, x, wk, wo, wq, wv):
        heads = n.attrs["heads"]
        q, k, v = self._proj(x, wq), self._proj(x, wk), self._proj(x, wv)
        *lead, s, h = q.shape
        hd = h // heads
        spl = lambda t: t.reshape(*lead, s, heads, hd)
        scores = jnp.einsum("...snd,...tnd->...nst", spl(q), spl(k)) \
            / math.sqrt(hd)
        attn = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("...nst,...tnd->...snd", attn, spl(v))
        return self._proj(out.reshape(*lead, s, h), wo)

    def _rel_pos_emb(self, s: int, h: int):
        # deterministic sinusoidal relative-position table [S, H]
        pos = jnp.arange(s)[:, None].astype(jnp.float32)
        i = jnp.arange(h // 2)[None, :].astype(jnp.float32)
        ang = pos / jnp.power(10000.0, 2 * i / h)
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

    def _op_xl_attention(self, n: Node, x, u, v, wk, wo, wq, wr, wv):
        """Transformer-XL relative attention: content stream (q+u)·k plus
        position stream (q+v)·r — strictly more compute than vanilla
        attention, mirroring the paper's XLNet observation (§5.2)."""
        heads = n.attrs["heads"]
        hidden = n.attrs["hidden"]
        s = x.shape[-2]
        q, k, vv = self._proj(x, wq), self._proj(x, wk), self._proj(x, wv)
        r = self._rel_pos_emb(s, hidden)            # [S, H]
        *lead, ss, h = q.shape
        hd = h // heads
        spl = lambda t: t.reshape(*lead, ss, heads, hd)
        if wr.ndim == 3:                            # merged: per-instance
            rp = jnp.einsum("sh,mhf->msf", r, wr)   # [M, S, H]
            rph = rp.reshape(rp.shape[0], ss, heads, hd)
            qc = q + u[:, None, None, :]
            qp = q + v[:, None, None, :]
            ac = jnp.einsum("mbsnd,mbtnd->mbnst", spl(qc), spl(k))
            bd = jnp.einsum("mbsnd,mtnd->mbnst", spl(qp), rph)
        else:
            rp = r @ wr
            rph = rp.reshape(ss, heads, hd)
            qc = q + u[None, None, :]
            qp = q + v[None, None, :]
            ac = jnp.einsum("bsnd,btnd->bnst", spl(qc), spl(k))
            bd = jnp.einsum("bsnd,tnd->bnst", spl(qp), rph)
        attn = jax.nn.softmax((ac + bd) / math.sqrt(hd), axis=-1)
        out = jnp.einsum("...nst,...tnd->...snd", attn, spl(vv))
        return self._proj(out.reshape(*lead, ss, h), wo)

    def _op_refmt(self, n: Node, x):
        m = self.g.merged_m
        src, dst = n.attrs["src"], n.attrs["dst"]
        if src == dst:
            return x
        if src == "batch":
            if x.ndim == 5:                    # [M, bs, C, h, w] -> NCHW
                t = jnp.moveaxis(x, 0, 1)      # [bs, M, C, h, w]
                return t.reshape(t.shape[0], -1, *t.shape[3:])
            # [M, bs, (S,) H] -> [bs, (S,) M*H]
            t = jnp.moveaxis(x, 0, -2)
            return t.reshape(*t.shape[:-2], m * x.shape[-1])
        # channel -> batch
        if x.ndim == 4:                        # [bs, M*C, h, w]
            c = x.shape[1] // m
            t = x.reshape(x.shape[0], m, c, *x.shape[2:])
            return jnp.moveaxis(t, 1, 0)
        h = x.shape[-1] // m
        t = x.reshape(*x.shape[:-1], m, h)
        return jnp.moveaxis(t, -2, 0)

    # -- evaluation -------------------------------------------------------------

    def __call__(self, x, *params):
        if len(params) != len(self.order):
            raise ValueError(
                f"expected {len(self.order)} params, got {len(params)}")
        pmap = dict(zip(self.order, params))
        env = {"input": x}
        for n in self.g.nodes:
            ins = [env[s] for s in n.inputs]
            w = [pmap[f"{n.id}.{k}"] for k in sorted(n.weights)]
            env[n.id] = self._eval(n, ins, w)
        return env[self.g.output]

    def _eval(self, n: Node, ins, w):
        k = n.kind
        if k == "conv2d":
            b, wt = w                               # sorted: b, w
            return self._conv(ins[0], wt, b, n.attrs["stride"],
                              n.attrs["padding"], n.attrs["groups"])
        if k == "dense":
            b, wt = w
            return self._mm_any(ins[0], wt, b)
        if k == "layernorm":
            beta, gamma = w
            return self._op_layernorm(n, ins[0], gamma, beta)
        if k == "groupnorm":
            beta, gamma = w
            return self._op_groupnorm(n, ins[0], gamma, beta)
        if k == "batchnorm":
            beta, gamma, mean, var = w
            return self._op_batchnorm(n, ins[0], gamma, beta, mean, var)
        if k == "attention":
            wk, wo, wq, wv = w
            return self._op_attention(n, ins[0], wk, wo, wq, wv)
        if k == "xl_attention":
            u, v, wk, wo, wq, wr, wv = w
            return self._op_xl_attention(n, ins[0], u, v, wk, wo, wq, wr, wv)
        if k == "relu":
            return jax.nn.relu(ins[0])
        if k == "gelu":
            return jax.nn.gelu(ins[0])
        if k == "add":
            return ins[0] + ins[1]
        if k == "maxpool2d":
            kk, s = n.attrs["k"], n.attrs["stride"]
            return jax.lax.reduce_window(
                ins[0], -jnp.inf, jax.lax.max,
                (1, 1, kk, kk), (1, 1, s, s), "VALID")
        if k == "global_avgpool":
            return ins[0].mean(axis=(2, 3), keepdims=True)
        if k == "flatten":
            return ins[0].reshape(ins[0].shape[0], -1)
        if k == "refmt":
            return self._op_refmt(n, ins[0])
        if k == "slice_m":
            return ins[0][n.attrs["index"]]
        if k == "stack_m":
            return jnp.stack(ins, axis=0)
        raise ValueError(f"unhandled op kind {k!r}")


def input_shape(g: Graph, bs: int) -> tuple:
    """Concrete input tensor shape for batch size ``bs``."""
    m = g.merged_m
    if g.layout == "channel":
        c, h, w = g.input_shape
        return (bs, m * c, h, w)
    if g.layout == "batch":
        return (m, bs, *g.input_shape)
    return (bs, *g.input_shape)


def as_fn(g: Graph, backend: str = "xla"):
    """Graph -> callable(x, *params)."""
    return Interpreter(g, backend)
