"""AOT pipeline: author -> merge -> lower -> artifacts/.

Runs ONCE at build time (``make artifacts``); Python is never on the
request path. For every (model, M, batch-size, backend) variant the
experiments need, this script:

  1. builds the single-instance graph (models/*),
  2. runs NETFUSE Algorithm 1 for the merged variants (netfuse.merge),
  3. lowers the interpreter's JAX function to **HLO text** — not
     ``.serialize()``: the image's xla_extension 0.5.1 rejects jax>=0.5
     protos with 64-bit instruction ids; the HLO text parser reassigns
     ids and round-trips cleanly (see /opt/xla-example/README.md),
  4. writes per-instance weight banks (``weights/<model>.nft``), golden
     input/output vectors for the Rust integration tests
     (``golden/*.nft``), and a ``manifest.json`` describing every
     executable's signature so the Rust runtime can load and drive them.

Artifact inventory (DESIGN.md §3):
  singles   4 models x bs in {1,2,4,8}            (Sequential/Concurrent/Hybrid)
  merged    4 models x M in {2,4,8,16,32}, bs=1   (Fig 5/7/8/9/10)
  bert+bs   bert merged, bs in {2,4,8} x M        (Fig 6)
  pallas    bert & resnet, single + M=4, bs=1     (kernel-integration path)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, netfuse, weights
from .graphir import Graph
from .model import Interpreter, input_shape, pack_inputs

MODELS = ("resnet", "resnext", "bert", "xlnet")
M_SWEEP = (2, 4, 8, 16, 32)
BS_SWEEP = (1, 2, 4, 8)
MAX_INSTANCES = 32
GOLDEN_M = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_graph(g: Graph, bs: int, backend: str) -> tuple[str, Interpreter,
                                                          tuple, tuple]:
    interp = Interpreter(g, backend)
    ishape = input_shape(g, bs)
    x_spec = jax.ShapeDtypeStruct(ishape, jnp.float32)
    p_specs = []
    wshapes = {}
    for n in g.nodes:
        for wname in sorted(n.weights):
            wshapes[f"{n.id}.{wname}"] = tuple(n.weights[wname])
    for key in interp.order:
        p_specs.append(jax.ShapeDtypeStruct(wshapes[key], jnp.float32))
    lowered = jax.jit(interp).lower(x_spec, *p_specs)
    oshape = tuple(lowered.out_info.shape)
    return to_hlo_text(lowered), interp, ishape, oshape


def act_bytes(g: Graph, bs: int) -> int:
    """Peak-ish activation workspace: sum of all intermediate tensors
    (upper bound; the paper's 'inference workspace')."""
    interp = Interpreter(g, "xla")
    sizes = []

    x = jnp.zeros(input_shape(g, bs), jnp.float32)
    banks = {}
    for n in g.nodes:
        for wname in sorted(n.weights):
            banks[f"{n.id}.{wname}"] = jnp.zeros(n.weights[wname], jnp.float32)
    env = {"input": x}
    for n in g.nodes:
        ins = [env[s] for s in n.inputs]
        w = [banks[f"{n.id}.{k}"] for k in sorted(n.weights)]
        env[n.id] = jax.eval_shape(
            lambda *a: interp._eval(n, list(a[:len(ins)]), list(a[len(ins):])),
            *ins, *w)
        # keep shapes abstract downstream
        env[n.id] = jax.ShapeDtypeStruct(env[n.id].shape, env[n.id].dtype)
        sizes.append(4 * int(np.prod(env[n.id].shape)))
    return int(sum(sizes))


def weight_bytes(g: Graph) -> int:
    return 4 * sum(int(np.prod(s)) for n in g.nodes
                   for s in n.weights.values())


def artifact_entry(name, g, bs, backend, hlo_path, interp, ishape, oshape):
    return {
        "name": name,
        "model": g.name.split("_x")[0],
        "m": g.merged_m,
        "bs": bs,
        "backend": backend,
        "hlo": os.path.basename(hlo_path),
        "layout": g.layout,
        "input": {"shape": list(ishape), "dtype": "f32"},
        "output": {"shape": list(oshape), "dtype": "f32"},
        "params": [{"key": k} for k in interp.order],
        "mem": {"weights_bytes": weight_bytes(g),
                "act_bytes": act_bytes(g, bs)},
        "graph": g.to_json(),
    }


def build_all(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    m_sweep = (2, 4) if quick else M_SWEEP
    bs_sweep = (1, 2) if quick else BS_SWEEP
    max_inst = max(m_sweep)

    manifest = {"version": 1, "artifacts": [], "models": {}}

    for mname in MODELS:
        g = models.build(mname)
        banks = weights.init_banks(g, max_inst)

        # ---- weight bank file (all instances, keyed m{i}/node.weight)
        bank_file = os.path.join(out_dir, "weights", f"{mname}.nft")
        flat = {}
        for i, bank in enumerate(banks):
            for k, v in bank.items():
                flat[f"m{i}/{k}"] = v
        weights.write_nft(bank_file, flat)

        manifest["models"][mname] = {
            "graph": g.to_json(),
            "instances": max_inst,
            "weights": f"weights/{mname}.nft",
        }

        # ---- single-model executables per batch size
        for bs in bs_sweep:
            name = f"{mname}_single_bs{bs}"
            hlo, interp, ishape, oshape = lower_graph(g, bs, "xla")
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(hlo)
            manifest["artifacts"].append(
                artifact_entry(name, g, bs, "xla", path, interp, ishape,
                               oshape))
            print(f"  {name}: {len(hlo)} chars")

        # ---- merged executables (bs=1; bert also sweeps bs for Fig 6)
        for m in m_sweep:
            mg = netfuse.merge(g, m)
            for bs in (bs_sweep if mname == "bert" else (1,)):
                name = f"{mname}_fused_m{m}_bs{bs}"
                hlo, interp, ishape, oshape = lower_graph(mg, bs, "xla")
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                with open(path, "w") as f:
                    f.write(hlo)
                manifest["artifacts"].append(
                    artifact_entry(name, mg, bs, "xla", path, interp,
                                   ishape, oshape))
            print(f"  {mname} fused m={m}")

        # ---- pallas-kernel variants (the L1 path the quickstart runs)
        if mname in ("resnet", "bert"):
            for g2, tag in ((g, "single"), (netfuse.merge(g, 4), "fused_m4")):
                name = f"{mname}_{tag}_bs1_pallas"
                hlo, interp, ishape, oshape = lower_graph(g2, 1, "pallas")
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                with open(path, "w") as f:
                    f.write(hlo)
                manifest["artifacts"].append(
                    artifact_entry(name, g2, 1, "pallas", path, interp,
                                   ishape, oshape))
            print(f"  {mname} pallas variants")

        # ---- golden vectors for the rust integration tests
        write_golden(out_dir, mname, g, banks)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")

    # build stamp so `make artifacts` can skip when inputs are unchanged
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write(source_digest())


def write_golden(out_dir, mname, g, banks):
    """Fixed-seed inputs + single & merged (M=2) outputs for rust tests."""
    m, bs = GOLDEN_M, 1
    mg = netfuse.merge(g, m)
    mw = netfuse.merge_weights(g, mg, banks[:m])
    single = Interpreter(g, "xla")
    merged = Interpreter(mg, "xla")
    rng = np.random.default_rng(12345)
    xs = [rng.normal(size=(bs, *g.input_shape)).astype(np.float32)
          for _ in range(m)]
    tensors = {}
    for i, x in enumerate(xs):
        tensors[f"x{i}"] = x
        y = single(jnp.asarray(x),
                   *[jnp.asarray(banks[i][k]) for k in single.order])
        tensors[f"y{i}"] = np.asarray(y)
    xm = pack_inputs(xs, mg.layout)
    ym = merged(xm, *[jnp.asarray(mw[k]) for k in merged.order])
    tensors["x_fused"] = np.asarray(xm)
    tensors["y_fused"] = np.asarray(ym)
    weights.write_nft(
        os.path.join(out_dir, "golden", f"{mname}.nft"), tensors)


def source_digest() -> str:
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for fast iteration")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    stamp = os.path.join(out, ".stamp")
    if os.path.exists(stamp):
        with open(stamp) as f:
            if f.read() == source_digest():
                print("artifacts up to date")
                return
    build_all(out, quick=args.quick)


if __name__ == "__main__":
    main()
