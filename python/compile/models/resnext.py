"""ResNeXt-mini: bottleneck blocks with grouped 3x3 convolutions.

Exercises the paper's "operations with input-weight local computations"
case (§3.1): merging M instances of a grouped convolution with G groups
yields one grouped convolution with M*G groups.
"""

from ..graphir import GraphBuilder, Graph


def _bottleneck(b: GraphBuilder, x: str, cin: int, cmid: int, cout: int,
                stride: int, cardinality: int) -> str:
    y = b.conv2d(x, cin, cmid, k=1, stride=1, padding=0)
    y = b.batchnorm(y, cmid)
    y = b.relu(y)
    # the ResNeXt signature op: grouped 3x3
    y = b.conv2d(y, cmid, cmid, k=3, stride=stride, groups=cardinality)
    y = b.batchnorm(y, cmid)
    y = b.relu(y)
    y = b.conv2d(y, cmid, cout, k=1, stride=1, padding=0)
    y = b.batchnorm(y, cout)
    if stride != 1 or cin != cout:
        x = b.conv2d(x, cin, cout, k=1, stride=stride, padding=0)
        x = b.batchnorm(x, cout)
    y = b.residual(y, x)
    return b.relu(y)


def resnext_mini(widths=(16, 32), blocks=2, cardinality=4, image=16,
                 classes=10) -> Graph:
    b = GraphBuilder("resnext", (3, image, image))
    x = b.conv2d("input", 3, widths[0], k=3, stride=1)
    x = b.batchnorm(x, widths[0])
    x = b.relu(x)
    cin = widths[0]
    for si, cout in enumerate(widths):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _bottleneck(b, x, cin, cout, cout, stride, cardinality)
            cin = cout
    x = b.global_avgpool(x)
    x = b.flatten(x)
    x = b.dense(x, cin, classes, mergeable=False)
    return b.build(x)
