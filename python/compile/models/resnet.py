"""ResNet-mini: conv stem + basic residual blocks + FC head.

Same layer vocabulary as ResNet-50 (conv2d / batchnorm / relu / residual
add / global average pool / dense head); reduced depth and width, 32x32
inputs. The final dense head models the paper's task-specific classifier:
it is marked ``mergeable=False`` so NETFUSE leaves it per-instance (§6).
"""

from ..graphir import GraphBuilder, Graph


def _basic_block(b: GraphBuilder, x: str, cin: int, cout: int,
                 stride: int) -> str:
    y = b.conv2d(x, cin, cout, k=3, stride=stride)
    y = b.batchnorm(y, cout)
    y = b.relu(y)
    y = b.conv2d(y, cout, cout, k=3, stride=1)
    y = b.batchnorm(y, cout)
    if stride != 1 or cin != cout:
        x = b.conv2d(x, cin, cout, k=1, stride=stride, padding=0)
        x = b.batchnorm(x, cout)
    y = b.residual(y, x)
    return b.relu(y)


def resnet_mini(widths=(8, 16, 32), blocks=2, image=16, classes=10) -> Graph:
    b = GraphBuilder("resnet", (3, image, image))
    x = b.conv2d("input", 3, widths[0], k=3, stride=1)
    x = b.batchnorm(x, widths[0])
    x = b.relu(x)
    cin = widths[0]
    for si, cout in enumerate(widths):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            x = _basic_block(b, x, cin, cout, stride)
            cin = cout
    x = b.global_avgpool(x)
    x = b.flatten(x)
    x = b.dense(x, cin, classes, mergeable=False)
    return b.build(x)
