"""BERT-mini: transformer encoder over pre-computed token embeddings.

The paper feeds synthetic embeddings of length 128 to BERT; we do the same
at reduced width. Layer = multi-head self-attention + residual + layernorm
+ FFN (dense-gelu-dense) + residual + layernorm. The classifier head is
task-specific and left unmerged (paper §6: merge the backbone only).
"""

from ..graphir import GraphBuilder, Graph


def encoder_layer(b: GraphBuilder, x: str, hidden: int, heads: int,
                  ffn_mult: int = 4) -> str:
    a = b.attention(x, hidden, heads)
    x = b.residual(x, a)
    x = b.layernorm(x, hidden)
    f = b.dense(x, hidden, hidden * ffn_mult)
    f = b.gelu(f)
    f = b.dense(f, hidden * ffn_mult, hidden)
    x = b.residual(x, f)
    x = b.layernorm(x, hidden)
    return x


def bert_mini(layers=2, hidden=32, heads=4, seq=16, classes=8) -> Graph:
    b = GraphBuilder("bert", (seq, hidden))
    x = "input"
    for _ in range(layers):
        x = encoder_layer(b, x, hidden, heads)
    x = b.dense(x, hidden, classes, mergeable=False)
    return b.build(x)
