"""XLNet-mini: Transformer-XL style encoder.

Strictly more compute per layer than BERT-mini: the relative-position
attention adds a position projection (wr) and the content/position bias
terms (u, v), mirroring the paper's observation that XLNet's extra
computation changes the concurrent baseline's behaviour (§5.2).
"""

from ..graphir import GraphBuilder, Graph


def xl_layer(b: GraphBuilder, x: str, hidden: int, heads: int,
             ffn_mult: int = 4) -> str:
    a = b.xl_attention(x, hidden, heads)
    x = b.residual(x, a)
    x = b.layernorm(x, hidden)
    f = b.dense(x, hidden, hidden * ffn_mult)
    f = b.gelu(f)
    f = b.dense(f, hidden * ffn_mult, hidden)
    x = b.residual(x, f)
    x = b.layernorm(x, hidden)
    return x


def xlnet_mini(layers=2, hidden=32, heads=4, seq=16, classes=8) -> Graph:
    b = GraphBuilder("xlnet", (seq, hidden))
    x = "input"
    for _ in range(layers):
        x = xl_layer(b, x, hidden, heads)
    x = b.dense(x, hidden, classes, mergeable=False)
    return b.build(x)
