"""Model zoo: mini, op-faithful versions of the paper's four models.

Each builder returns a single-instance :class:`graphir.Graph`. Scale
(depth/width) is reduced so the CPU PJRT backend stays tractable; op
*kinds* and topology — the things NETFUSE's Algorithm 1 actually exercises
— match the originals (see DESIGN.md §4).
"""

from .resnet import resnet_mini
from .resnext import resnext_mini
from .bert import bert_mini
from .xlnet import xlnet_mini

BUILDERS = {
    "resnet": resnet_mini,
    "resnext": resnext_mini,
    "bert": bert_mini,
    "xlnet": xlnet_mini,
}


def build(name: str, **kw):
    return BUILDERS[name](**kw)
