"""Declarative graph IR for NETFUSE.

A DNN is a DAG of op nodes. This IR is the interchange format between the
Python author/merge/lowering path and the Rust merge planner
(``rust/src/graph``): both sides round-trip the same JSON.

The IR deliberately mirrors the subset of TorchScript graphs the paper's
implementation manipulates: op kind + attributes + weight slots, and the
*merge dimension* classification of Algorithm 1 (Batch / Channel /
DontCare).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Merge-dimension classification (paper §3, Algorithm 1 lines 12-16).
# ---------------------------------------------------------------------------

BATCH = "Batch"
CHANNEL = "Channel"
DONTCARE = "DontCare"

#: op kind -> merge dimension required when fusing M instances.
MERGE_DIM = {
    "dense": BATCH,          # matmul -> batch matmul (concat on batch)
    "attention": BATCH,      # composed of matmuls -> batch matmuls
    "xl_attention": BATCH,
    "conv2d": CHANNEL,       # conv -> grouped conv (concat on channel)
    "layernorm": CHANNEL,    # layer norm -> group norm
    "batchnorm": CHANNEL,    # per-channel already
    "groupnorm": CHANNEL,
    # non-trainable ops merge seamlessly (paper §3.1)
    "relu": DONTCARE,
    "gelu": DONTCARE,
    "add": DONTCARE,
    "maxpool2d": DONTCARE,
    "global_avgpool": DONTCARE,
    "flatten": DONTCARE,
    "refmt": DONTCARE,       # layout fix-up inserted by Algorithm 1
    "slice_m": DONTCARE,     # per-instance slice (unmerged heads, §6)
    "stack_m": DONTCARE,     # recombine per-instance head outputs
}

#: ops that carry weights (everything else is non-trainable).
TRAINABLE = {
    "conv2d", "dense", "layernorm", "batchnorm", "groupnorm",
    "attention", "xl_attention",
}

ALL_KINDS = sorted(MERGE_DIM)


@dataclass
class Node:
    """One operation in the graph.

    id      -- unique string id within the graph.
    kind    -- one of ALL_KINDS.
    inputs  -- ids of producer nodes, or the special id "input".
    attrs   -- kind-specific attributes (ints/floats/strings/bools).
    weights -- ordered {name: shape} of this node's parameters.
    mergeable -- False for task-specific layers left un-merged (paper §6:
                 common backbones are merged, customized heads are not).
    """

    id: str
    kind: str
    inputs: list[str]
    attrs: dict = field(default_factory=dict)
    weights: dict = field(default_factory=dict)
    mergeable: bool = True

    def to_json(self) -> dict:
        return {
            "id": self.id,
            "kind": self.kind,
            "inputs": list(self.inputs),
            "attrs": dict(self.attrs),
            "weights": {k: list(v) for k, v in self.weights.items()},
            "mergeable": self.mergeable,
        }

    @staticmethod
    def from_json(d: dict) -> "Node":
        return Node(
            id=d["id"],
            kind=d["kind"],
            inputs=list(d["inputs"]),
            attrs=dict(d.get("attrs", {})),
            weights={k: tuple(v) for k, v in d.get("weights", {}).items()},
            mergeable=bool(d.get("mergeable", True)),
        )


@dataclass
class Graph:
    """A DNN as a topologically ordered list of nodes.

    input_shape excludes the batch dimension: for CNNs (C, H, W), for
    transformers (S, H). ``layout`` records how a *merged* graph packs M
    instances: "single" (unmerged), "channel" ([bs, M*C, ...]) or "batch"
    ([M, bs, ...]).
    """

    name: str
    input_shape: tuple
    nodes: list[Node]
    output: str
    merged_m: int = 1
    layout: str = "single"

    def node(self, nid: str) -> Node:
        for n in self.nodes:
            if n.id == nid:
                return n
        raise KeyError(f"no node {nid!r} in graph {self.name!r}")

    def consumers(self, nid: str) -> list[Node]:
        return [n for n in self.nodes if nid in n.inputs]

    def validate(self) -> None:
        """Structural checks shared with the Rust side."""
        seen: set[str] = set()
        if not self.nodes:
            raise ValueError("empty graph")
        for n in self.nodes:
            if n.id in seen or n.id == "input":
                raise ValueError(f"duplicate/reserved node id {n.id!r}")
            if n.kind not in MERGE_DIM:
                raise ValueError(f"unknown op kind {n.kind!r}")
            for src in n.inputs:
                if src != "input" and src not in seen:
                    raise ValueError(
                        f"node {n.id!r} uses {src!r} before definition "
                        "(graph must be topologically ordered)")
            if n.kind in TRAINABLE and n.kind != "refmt" and not n.weights:
                raise ValueError(f"trainable node {n.id!r} has no weights")
            if n.kind not in TRAINABLE and n.weights:
                raise ValueError(f"non-trainable node {n.id!r} has weights")
            seen.add(n.id)
        if self.output not in seen:
            raise ValueError(f"output {self.output!r} is not a node")

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "nodes": [n.to_json() for n in self.nodes],
            "output": self.output,
            "merged_m": self.merged_m,
            "layout": self.layout,
        }

    @staticmethod
    def from_json(d: dict) -> "Graph":
        return Graph(
            name=d["name"],
            input_shape=tuple(d["input_shape"]),
            nodes=[Node.from_json(n) for n in d["nodes"]],
            output=d["output"],
            merged_m=int(d.get("merged_m", 1)),
            layout=d.get("layout", "single"),
        )

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=1)

    @staticmethod
    def loads(s: str) -> "Graph":
        return Graph.from_json(json.loads(s))


# ---------------------------------------------------------------------------
# Builder helper
# ---------------------------------------------------------------------------

class GraphBuilder:
    """Tiny fluent builder used by python/compile/models/*."""

    def __init__(self, name: str, input_shape: tuple):
        self.name = name
        self.input_shape = tuple(input_shape)
        self.nodes: list[Node] = []
        self._n = 0

    def fresh(self, kind: str) -> str:
        self._n += 1
        return f"{kind}_{self._n}"

    def add(self, kind: str, inputs, attrs=None, weights=None,
            mergeable: bool = True, id: str | None = None) -> str:
        if isinstance(inputs, str):
            inputs = [inputs]
        nid = id or self.fresh(kind)
        self.nodes.append(Node(
            id=nid, kind=kind, inputs=list(inputs),
            attrs=dict(attrs or {}), weights=dict(weights or {}),
            mergeable=mergeable,
        ))
        return nid

    # -- trainable ops ------------------------------------------------------
    def conv2d(self, x, cin, cout, k, stride=1, padding=None, groups=1,
               mergeable=True):
        if padding is None:
            padding = k // 2
        return self.add(
            "conv2d", x,
            attrs={"cin": cin, "cout": cout, "k": k, "stride": stride,
                   "padding": padding, "groups": groups},
            weights={"w": (cout, cin // groups, k, k), "b": (cout,)},
            mergeable=mergeable)

    def dense(self, x, fin, fout, mergeable=True):
        return self.add("dense", x, attrs={"fin": fin, "fout": fout},
                        weights={"w": (fin, fout), "b": (fout,)},
                        mergeable=mergeable)

    def layernorm(self, x, dim):
        return self.add("layernorm", x, attrs={"dim": dim},
                        weights={"gamma": (dim,), "beta": (dim,)})

    def batchnorm(self, x, c):
        return self.add("batchnorm", x, attrs={"c": c},
                        weights={"gamma": (c,), "beta": (c,),
                                 "mean": (c,), "var": (c,)})

    def groupnorm(self, x, c, groups):
        return self.add("groupnorm", x, attrs={"c": c, "groups": groups},
                        weights={"gamma": (c,), "beta": (c,)})

    def attention(self, x, hidden, heads):
        w = {"wq": (hidden, hidden), "wk": (hidden, hidden),
             "wv": (hidden, hidden), "wo": (hidden, hidden)}
        return self.add("attention", x,
                        attrs={"hidden": hidden, "heads": heads}, weights=w)

    def xl_attention(self, x, hidden, heads):
        # Transformer-XL style: extra relative-position projection and the
        # two learned bias vectors (u: content bias, v: position bias).
        w = {"wq": (hidden, hidden), "wk": (hidden, hidden),
             "wv": (hidden, hidden), "wo": (hidden, hidden),
             "wr": (hidden, hidden), "u": (hidden,), "v": (hidden,)}
        return self.add("xl_attention", x,
                        attrs={"hidden": hidden, "heads": heads}, weights=w)

    # -- non-trainable ops --------------------------------------------------
    def relu(self, x):
        return self.add("relu", x)

    def gelu(self, x):
        return self.add("gelu", x)

    def residual(self, x, y):
        return self.add("add", [x, y])

    def maxpool2d(self, x, k=2, stride=2):
        return self.add("maxpool2d", x, attrs={"k": k, "stride": stride})

    def global_avgpool(self, x):
        return self.add("global_avgpool", x)

    def flatten(self, x):
        return self.add("flatten", x)

    def build(self, output: str) -> Graph:
        g = Graph(self.name, self.input_shape, self.nodes, output)
        g.validate()
        return g
